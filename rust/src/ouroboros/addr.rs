//! The device-tagged global address namespace for multi-device groups.
//!
//! A single simulated device's heap lives in a 32-bit byte-address
//! space. The allocation service's `DeviceGroup` topology owns several
//! devices, each with its own [`super::heap::Heap`], so service clients
//! see **global** addresses: the owning device's group index in the
//! high bits, the device-local heap byte address in the low bits.
//!
//! ```text
//!  31           26 25                         0
//! +---------------+---------------------------+
//! |   device id   |  local heap byte address  |
//! +---------------+---------------------------+
//! ```
//!
//! The split gives every device a 64 MiB window ([`DEVICE_SPAN`]) —
//! twice the default 32 MiB heap — and up to [`MAX_DEVICES`] group
//! members. Device 0's global addresses are numerically identical to
//! its local addresses, so the single-device topology is bit-for-bit
//! the pre-group address space.
//!
//! Everything below the service speaks local addresses (the allocator
//! variants, the heap, the warp paths); the service encodes on the way
//! out of a completed alloc and decodes on the way into a submitted
//! free — including the `InvalidFree` fast-reject, which must bounds-
//! check both the device tag and the local chunk index.

use std::fmt;

/// Bit position of the device id inside a global address.
pub const DEVICE_SHIFT: u32 = 26;
/// Bytes of local address space per group device (64 MiB).
pub const DEVICE_SPAN: u32 = 1 << DEVICE_SHIFT;
/// Maximum devices a group can address (64).
pub const MAX_DEVICES: u32 = 1 << (32 - DEVICE_SHIFT);

/// A device-tagged allocation address handed out by the allocation
/// service: group device id in the high bits, device-local heap byte
/// address in the low bits. Opaque to clients — its only contract is
/// that [`GlobalAddr::device`]/[`GlobalAddr::local`] round-trip what
/// the service encoded.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GlobalAddr(u32);

impl GlobalAddr {
    /// Tag a device-local address with its owning device's group index.
    #[inline]
    pub fn new(device: u32, local: u32) -> Self {
        debug_assert!(device < MAX_DEVICES, "device id {device} out of range");
        debug_assert!(local < DEVICE_SPAN, "local address {local:#x} overflows device window");
        GlobalAddr((device << DEVICE_SHIFT) | local)
    }

    /// Reinterpret a raw u32 as a global address (no validation — the
    /// service's submit path is where garbage gets rejected).
    #[inline]
    pub fn from_raw(raw: u32) -> Self {
        GlobalAddr(raw)
    }

    /// The raw encoded word (what `AllocError::InvalidFree` carries).
    #[inline]
    pub fn raw(self) -> u32 {
        self.0
    }

    /// Owning device's group index.
    #[inline]
    pub fn device(self) -> u32 {
        self.0 >> DEVICE_SHIFT
    }

    /// Device-local heap byte address.
    #[inline]
    pub fn local(self) -> u32 {
        self.0 & (DEVICE_SPAN - 1)
    }

    /// Whether the device tag names a member of a `members`-device group
    /// — the first half of every service-side free fast-reject, and the
    /// guard migration/forwarding paths use before indexing the group.
    #[inline]
    pub fn device_in(self, members: usize) -> bool {
        (self.device() as usize) < members
    }

    /// The same local address re-tagged onto another group member.
    /// Live-set migration mints the forwarding *value* this way when the
    /// destination page happens to share the source's local offset; it
    /// is also the cheapest way to build test fixtures that alias a
    /// local address across devices.
    #[inline]
    pub fn retag(self, device: u32) -> Self {
        GlobalAddr::new(device, self.local())
    }
}

impl fmt::Debug for GlobalAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "d{}+{:#x}", self.device(), self.local())
    }
}

impl fmt::Display for GlobalAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        for (dev, local) in [(0u32, 0u32), (0, 0x3FF_FFFF), (1, 16), (7, 8192), (63, 0x123_4560)] {
            let g = GlobalAddr::new(dev, local);
            assert_eq!(g.device(), dev, "{g}");
            assert_eq!(g.local(), local, "{g}");
            assert_eq!(GlobalAddr::from_raw(g.raw()), g);
        }
    }

    #[test]
    fn device_zero_is_identity() {
        // The single-device topology keeps the pre-group address space.
        for local in [0u32, 16, 1000, DEVICE_SPAN - 1] {
            assert_eq!(GlobalAddr::new(0, local).raw(), local);
        }
    }

    #[test]
    fn span_fits_default_heap() {
        // The default 32 MiB heap must fit the per-device window.
        let cfg = super::super::params::HeapConfig::default();
        assert!(cfg.heap_bytes() <= DEVICE_SPAN as u64);
        assert_eq!(MAX_DEVICES, 64);
    }

    #[test]
    fn display_decodes_tag() {
        let g = GlobalAddr::new(3, 0x40);
        assert_eq!(format!("{g}"), "d3+0x40");
        assert_eq!(format!("{g:?}"), "d3+0x40");
    }

    #[test]
    fn device_in_checks_group_bounds() {
        let g = GlobalAddr::new(2, 0x40);
        assert!(g.device_in(3));
        assert!(!g.device_in(2), "device 2 is not a member of a 2-group");
        assert!(!g.device_in(0));
        // Device 0 (the untagged space) is a member of any group.
        assert!(GlobalAddr::new(0, 16).device_in(1));
    }

    #[test]
    fn retag_moves_device_keeps_local() {
        let g = GlobalAddr::new(1, 0x1230);
        let m = g.retag(5);
        assert_eq!(m.device(), 5);
        assert_eq!(m.local(), g.local());
        assert_eq!(m.retag(1), g);
    }

    #[test]
    fn ordering_groups_by_device() {
        let a = GlobalAddr::new(0, DEVICE_SPAN - 1);
        let b = GlobalAddr::new(1, 0);
        assert!(a < b, "device 1 addresses sort after all of device 0");
    }
}
