//! Chunk headers: ownership state, occupancy bitmap, free-page count.
//!
//! A chunk is CHUNK_SIZE bytes of heap carved into pages of its owning
//! queue's size. The header's occupancy bitmap is scanned with atomic
//! bit-sets to reserve pages ("first obtaining a chunk index, then
//! scanning the chunk for free pages" — paper §4.2). Out-of-range bits
//! (queues with < MAX_PAGES_PER_CHUNK pages) are pre-set to 1, the same
//! convention the Pallas `bitmap_scan` kernel assumes.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::simt::{DevCtx, HotSpot};

use super::params::{pages_per_chunk, BITMAP_WORDS};

/// Chunk ownership states.
pub const STATE_FREE: u32 = 0;
/// Owned by a size-class queue; pages may be allocated from it.
pub const STATE_OWNED: u32 = 1;
/// Used as virtual-queue storage (the Ouroboros self-eating property).
pub const STATE_QUEUE_STORAGE: u32 = 2;

pub struct ChunkHeader {
    state: AtomicU32,
    queue: AtomicU32,
    free_count: AtomicU32,
    bitmap: [AtomicU32; BITMAP_WORDS],
    hot: HotSpot,
}

impl Default for ChunkHeader {
    fn default() -> Self {
        ChunkHeader {
            state: AtomicU32::new(STATE_FREE),
            queue: AtomicU32::new(0),
            free_count: AtomicU32::new(0),
            bitmap: std::array::from_fn(|_| AtomicU32::new(0)),
            // Header words interleave over bitmap words / rotate across
            // chunks — 4-way spread on the device atomic unit.
            hot: HotSpot::with_ways(4),
        }
    }
}

impl ChunkHeader {
    pub fn state(&self) -> u32 {
        // ordering: Acquire; pairs with set_state/publish Release
        self.state.load(Ordering::Acquire)
    }

    pub fn set_state(&self, s: u32) {
        // ordering: Release; header writes visible with the state
        self.state.store(s, Ordering::Release);
    }

    /// CAS on the ownership state (used by sweep/claim transitions).
    pub fn cas_state(&self, from: u32, to: u32) -> bool {
        self.state
            // ordering: AcqRel CAS; win orders init, loss observes
            .compare_exchange(from, to, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    pub fn queue(&self) -> usize {
        // ordering: Acquire; header published by init Release
        self.queue.load(Ordering::Acquire) as usize
    }

    pub fn free_count(&self) -> u32 {
        // ordering: Acquire; header published by init Release
        self.free_count.load(Ordering::Acquire)
    }

    pub fn hot(&self) -> &HotSpot {
        &self.hot
    }

    /// Take ownership for size-class `q`: all pages free, out-of-range
    /// bits pre-set. Caller must hold exclusive claim (fresh or reused
    /// chunk from the heap).
    pub fn init_for_queue(&self, ctx: &DevCtx, q: usize) {
        let ppc = pages_per_chunk(q);
        // ordering: Release; visible before OWNED publish
        self.queue.store(q as u32, Ordering::Release);
        self.free_count.store(ppc, Ordering::Release);
        for (w, word) in self.bitmap.iter().enumerate() {
            let lo = (w as u32) * 32;
            let v = if lo + 32 <= ppc {
                0
            } else if lo >= ppc {
                u32::MAX
            } else {
                !((1u32 << (ppc - lo)) - 1)
            };
            // ordering: Release; bitmap init precedes OWNED publish
            word.store(v, Ordering::Release);
        }
        ctx.charge_mem(BITMAP_WORDS as u64 + 2);
        self.state.store(STATE_OWNED, Ordering::Release); // ordering: Release; publishes the header
    }

    /// Atomically reserve the first free page. Returns the page index and
    /// the free count *after* this reservation, or `None` if the chunk
    /// raced to full.
    ///
    /// The bitmap words of the hot front chunk are write-hot lines: the
    /// scan pays `hot_read_stall` per word — a memory-system cost that is
    /// identical across toolchains, which is why the chunk allocators sit
    /// at CUDA/SYCL parity in the paper while the RMW-bound page
    /// allocators do not (§5).
    pub fn reserve_page(&self, ctx: &DevCtx) -> Option<(u32, u32)> {
        for (w, word) in self.bitmap.iter().enumerate() {
            let mut cur = ctx.hot_read(word, &self.hot);
            loop {
                if cur == u32::MAX {
                    break; // word full; next word
                }
                let bit = (!cur).trailing_zeros();
                let prev = ctx.fetch_or(word, 1 << bit, &self.hot);
                if prev & (1 << bit) == 0 {
                    // Won the bit.
                    let left = ctx.fetch_sub(&self.free_count, 1, &self.hot) - 1;
                    return Some((w as u32 * 32 + bit, left));
                }
                // Raced; rescan this word with the fresher value.
                cur = prev | (1 << bit);
            }
        }
        None
    }

    /// Atomically mark a *specific* page allocated (page-queue path: the
    /// page identity came out of the queue, not from a scan). `false`
    /// means the bit was already set — the queue yielded a duplicate.
    pub fn acquire_page(&self, ctx: &DevCtx, page: u32) -> bool {
        let (w, bit) = ((page / 32) as usize, page % 32);
        let prev = ctx.fetch_or(&self.bitmap[w], 1 << bit, &self.hot);
        if prev & (1 << bit) != 0 {
            return false;
        }
        ctx.fetch_sub(&self.free_count, 1, &self.hot);
        true
    }

    /// Release `page`. Returns `(was_allocated, free_count_before)`; a
    /// `false` flags a double free.
    pub fn release_page(&self, ctx: &DevCtx, page: u32) -> (bool, u32) {
        let (w, bit) = ((page / 32) as usize, page % 32);
        let prev = ctx.fetch_and(&self.bitmap[w], !(1u32 << bit), &self.hot);
        if prev & (1 << bit) == 0 {
            return (false, self.free_count());
        }
        let before = ctx.fetch_add(&self.free_count, 1, &self.hot);
        (true, before)
    }

    /// Racy snapshot of the occupancy bitmap (exported to the XLA batch
    /// planner; exact at quiescence).
    pub fn snapshot_bitmap(&self) -> [u32; BITMAP_WORDS] {
        // ordering: Acquire snapshot; pairs with bit-set Release
        std::array::from_fn(|w| self.bitmap[w].load(Ordering::Acquire))
    }

    /// True iff every in-range page is free (exact at quiescence).
    pub fn is_fully_free(&self) -> bool {
        self.state() == STATE_OWNED
            && self.free_count() == pages_per_chunk(self.queue())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Backend, Cuda};
    use crate::simt::DevCtx;

    fn ctx<'a>(b: &'a dyn Backend) -> DevCtx<'a> {
        DevCtx::new(b, 1000.0, 0)
    }

    #[test]
    fn init_sets_out_of_range_bits() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = ChunkHeader::default();
        h.init_for_queue(&c, 6); // 1024 B pages -> 8 pages
        let bm = h.snapshot_bitmap();
        assert_eq!(bm[0], !0xFF); // low 8 bits free
        for w in 1..BITMAP_WORDS {
            assert_eq!(bm[w], u32::MAX);
        }
        assert_eq!(h.free_count(), 8);
        assert_eq!(h.queue(), 6);
        assert_eq!(h.state(), STATE_OWNED);
    }

    #[test]
    fn init_queue0_all_free() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = ChunkHeader::default();
        h.init_for_queue(&c, 0);
        assert!(h.snapshot_bitmap().iter().all(|&w| w == 0));
        assert_eq!(h.free_count(), 512);
    }

    #[test]
    fn reserve_all_pages_then_full() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = ChunkHeader::default();
        h.init_for_queue(&c, 6);
        let mut pages = Vec::new();
        while let Some((p, _)) = h.reserve_page(&c) {
            pages.push(p);
        }
        assert_eq!(pages, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(h.free_count(), 0);
        assert!(h.reserve_page(&c).is_none());
    }

    #[test]
    fn release_and_reacquire_lowest_first() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = ChunkHeader::default();
        h.init_for_queue(&c, 6);
        while h.reserve_page(&c).is_some() {}
        let (ok, before) = h.release_page(&c, 5);
        assert!(ok);
        assert_eq!(before, 0);
        let (ok, _) = h.release_page(&c, 2);
        assert!(ok);
        // First-free scan returns the lowest released page.
        assert_eq!(h.reserve_page(&c).unwrap().0, 2);
        assert_eq!(h.reserve_page(&c).unwrap().0, 5);
    }

    #[test]
    fn double_free_detected() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = ChunkHeader::default();
        h.init_for_queue(&c, 6);
        let (p, _) = h.reserve_page(&c).unwrap();
        assert!(h.release_page(&c, p).0);
        assert!(!h.release_page(&c, p).0);
    }

    #[test]
    fn fully_free_detection() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = ChunkHeader::default();
        h.init_for_queue(&c, 9); // one 8 KiB page
        assert!(h.is_fully_free());
        let (p, left) = h.reserve_page(&c).unwrap();
        assert_eq!((p, left), (0, 0));
        assert!(!h.is_fully_free());
        h.release_page(&c, p);
        assert!(h.is_fully_free());
    }

    #[test]
    fn concurrent_reservation_no_duplicates() {
        let h = std::sync::Arc::new(ChunkHeader::default());
        let b = Cuda::new();
        h.init_for_queue(&ctx(&b), 0); // 512 pages
        let got: std::sync::Mutex<Vec<u32>> = Default::default();
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = h.clone();
                let got = &got;
                s.spawn(move || {
                    let b = Cuda::new();
                    let c = DevCtx::new(&b, 1000.0, t);
                    let mut mine = Vec::new();
                    while let Some((p, _)) = h.reserve_page(&c) {
                        mine.push(p);
                    }
                    got.lock().unwrap().extend(mine);
                });
            }
        });
        let mut pages = got.into_inner().unwrap();
        pages.sort_unstable();
        assert_eq!(pages, (0..512).collect::<Vec<_>>());
        assert_eq!(h.free_count(), 0);
    }
}
