//! The standard Ouroboros index queue: a statically sized lock-free ring.
//!
//! Layout and protocol follow the original (Winter et al., ICS'20 §3.1):
//! `count` gates admission, `front`/`back` hand out unique ring positions
//! via fetch-add, and each slot is a tiny state machine — 0 means empty,
//! `v+1` means occupied by index `v`. A dequeuer whose reserved slot is
//! still empty spins (the matching enqueuer has reserved but not yet
//! published); that spin is where the backoff policy (nanosleep vs fence)
//! matters and is charged accordingly.
//!
//! The standard queues are memory-hungry (capacity must cover the worst
//! case of every page/chunk sitting in one queue) — that is precisely the
//! cost the paper's virtualized variants remove.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::simt::{DevCtx, HotSpot};

use super::error::AllocError;
use super::queue::IdQueue;

const EMPTY: u32 = 0;
/// Spin iterations before declaring the queue corrupted (test guard —
/// a correct run never gets near this).
const SPIN_LIMIT: u32 = 10_000_000;

pub struct IndexQueue {
    slots: Vec<AtomicU32>,
    /// `slots.len() - 1`; capacities are rounded up to a power of two so
    /// ring positions map to slots with a mask instead of the hardware
    /// divide `pos % cap` cost on every slot touch.
    mask: u32,
    front: AtomicU32,
    back: AtomicU32,
    /// Interpreted as i32: transiently negative under contended admission.
    count: AtomicU32,
    hot: HotSpot,
}

impl IndexQueue {
    /// Build a queue of at least `capacity` entries. The capacity is
    /// rounded **up** to the next power of two (so `capacity()` and
    /// `metadata_bytes()` report the rounded, actually-allocated size) —
    /// admission is gated on the real slot count, never on the request.
    pub fn new(capacity: u32) -> Self {
        assert!(capacity > 0);
        assert!(
            capacity <= 1 << 31,
            "index queue capacity {capacity} cannot round to a power of two"
        );
        let cap = capacity.next_power_of_two();
        IndexQueue {
            slots: (0..cap).map(|_| AtomicU32::new(EMPTY)).collect(),
            mask: cap - 1,
            front: AtomicU32::new(0),
            back: AtomicU32::new(0),
            count: AtomicU32::new(0),
            hot: HotSpot::new(),
        }
    }

    #[inline]
    fn cap(&self) -> u32 {
        self.slots.len() as u32
    }

    #[inline]
    fn slot(&self, pos: u32) -> &AtomicU32 {
        &self.slots[(pos & self.mask) as usize]
    }

    /// Publish `v` into the reserved ring position.
    fn publish(&self, ctx: &DevCtx, pos: u32, v: u32) -> Result<(), AllocError> {
        debug_assert_ne!(v.wrapping_add(1), EMPTY);
        let mut attempt = 0;
        loop {
            if self.slot(pos).compare_exchange(
                EMPTY,
                v + 1,
                Ordering::AcqRel, // ordering: AcqRel publish CAS; pairs with consume swap
                Ordering::Acquire,
            ).is_ok() {
                ctx.charge_mem(1);
                return Ok(());
            }
            // Slot still holds the previous generation's value: a slow
            // dequeuer hasn't consumed it yet. Back off and retry.
            ctx.backoff(&self.hot, attempt.min(8));
            attempt += 1;
            if attempt > SPIN_LIMIT {
                return Err(AllocError::QueueCorrupt);
            }
        }
    }

    /// Consume the value from a reserved ring position.
    fn consume(&self, ctx: &DevCtx, pos: u32) -> Result<u32, AllocError> {
        let mut attempt = 0;
        loop {
            // ordering: AcqRel consume; pairs with publish CAS
            let v = self.slot(pos).swap(EMPTY, Ordering::AcqRel);
            ctx.charge_mem(1);
            if v != EMPTY {
                return Ok(v - 1);
            }
            // Matching enqueuer reserved this position but hasn't
            // published yet.
            ctx.backoff(&self.hot, attempt.min(8));
            attempt += 1;
            if attempt > SPIN_LIMIT {
                return Err(AllocError::QueueCorrupt);
            }
        }
    }
}

impl IdQueue for IndexQueue {
    fn try_enqueue(&self, ctx: &DevCtx, v: u32) -> Result<(), AllocError> {
        let _g = ctx.contend(&self.hot);
        // Admission: claim space, undo on overflow.
        let prev = ctx.fetch_add(&self.count, 1, &self.hot) as i32;
        if prev >= self.cap() as i32 {
            ctx.fetch_sub(&self.count, 1, &self.hot);
            return Err(AllocError::OutOfMemory);
        }
        let pos = ctx.fetch_add(&self.back, 1, &self.hot);
        self.publish(ctx, pos, v)
    }

    fn try_dequeue(&self, ctx: &DevCtx) -> Option<u32> {
        let _g = ctx.contend(&self.hot);
        let prev = ctx.fetch_sub(&self.count, 1, &self.hot) as i32;
        if prev <= 0 {
            ctx.fetch_add(&self.count, 1, &self.hot);
            return None;
        }
        let pos = ctx.fetch_add(&self.front, 1, &self.hot);
        // QueueCorrupt here would be an implementation bug; surfacing it
        // as a panic keeps the allocator API clean (tests would trip it).
        Some(self.consume(ctx, pos).expect("index queue corrupted"))
    }

    fn peek(&self, ctx: &DevCtx) -> Option<u32> {
        if (ctx.load(&self.count) as i32) <= 0 {
            return None;
        }
        // ordering: Acquire; head sample precedes slot read
        let pos = self.front.load(Ordering::Acquire);
        let v = ctx.hot_read(self.slot(pos), &self.hot);
        (v != EMPTY).then(|| v - 1)
    }

    fn hot(&self) -> &HotSpot {
        &self.hot
    }

    fn len(&self) -> u32 {
        // ordering: transient count sample; len heuristic
        (self.count.load(Ordering::Relaxed) as i32).max(0) as u32
    }

    fn capacity(&self) -> u32 {
        self.cap()
    }

    fn metadata_bytes(&self) -> u64 {
        // Slot array + 3 counters.
        self.slots.len() as u64 * 4 + 12
    }

    /// Coalesced dequeue: one admission CAS loop + one head fetch-add for
    /// the whole warp group, then per-slot consumes. This is the
    /// `__activemask()`-vote fast path of the optimised CUDA build.
    fn bulk_dequeue(&self, ctx: &DevCtx, n: u32, out: &mut Vec<u32>) {
        if n == 0 {
            return;
        }
        let _g = ctx.contend(&self.hot);
        // Claim as many as available, up to n.
        let take = loop {
            let c = ctx.load(&self.count) as i32;
            let avail = c.max(0) as u32;
            let take = avail.min(n);
            if take == 0 {
                return;
            }
            if ctx
                .cas(&self.count, c as u32, (c - take as i32) as u32, &self.hot)
                .is_ok()
            {
                break take;
            }
        };
        let pos0 = ctx.fetch_add(&self.front, take, &self.hot);
        for i in 0..take {
            out.push(
                self.consume(ctx, pos0.wrapping_add(i))
                    .expect("index queue corrupted"),
            );
        }
    }

    /// Coalesced enqueue: one admission CAS loop + one tail fetch-add.
    fn bulk_enqueue(&self, ctx: &DevCtx, vs: &[u32]) -> Result<(), AllocError> {
        if vs.is_empty() {
            return Ok(());
        }
        let _g = ctx.contend(&self.hot);
        let k = vs.len() as u32;
        loop {
            let c = ctx.load(&self.count) as i32;
            if c.max(0) as u32 + k > self.cap() {
                return Err(AllocError::OutOfMemory);
            }
            if ctx
                .cas(&self.count, c as u32, (c + k as i32) as u32, &self.hot)
                .is_ok()
            {
                break;
            }
        }
        let pos0 = ctx.fetch_add(&self.back, k, &self.hot);
        for (i, &v) in vs.iter().enumerate() {
            self.publish(ctx, pos0.wrapping_add(i as u32), v)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Backend, Cuda};

    fn ctx<'a>(b: &'a dyn Backend) -> DevCtx<'a> {
        DevCtx::new(b, 1000.0, 0)
    }

    #[test]
    fn fifo_when_sequential() {
        let b = Cuda::new();
        let c = ctx(&b);
        let q = IndexQueue::new(8);
        for v in 10..14 {
            q.try_enqueue(&c, v).unwrap();
        }
        assert_eq!(q.len(), 4);
        for v in 10..14 {
            assert_eq!(q.try_dequeue(&c), Some(v));
        }
        assert_eq!(q.try_dequeue(&c), None);
    }

    #[test]
    fn full_queue_rejects() {
        let b = Cuda::new();
        let c = ctx(&b);
        let q = IndexQueue::new(2);
        q.try_enqueue(&c, 1).unwrap();
        q.try_enqueue(&c, 2).unwrap();
        assert_eq!(q.try_enqueue(&c, 3), Err(AllocError::OutOfMemory));
        assert_eq!(q.len(), 2);
        // Draining restores capacity.
        q.try_dequeue(&c).unwrap();
        q.try_enqueue(&c, 3).unwrap();
    }

    #[test]
    fn wraps_around_ring() {
        let b = Cuda::new();
        let c = ctx(&b);
        let q = IndexQueue::new(3);
        for round in 0..10u32 {
            q.try_enqueue(&c, round).unwrap();
            assert_eq!(q.try_dequeue(&c), Some(round));
        }
    }

    #[test]
    fn value_zero_roundtrips() {
        let b = Cuda::new();
        let c = ctx(&b);
        let q = IndexQueue::new(4);
        q.try_enqueue(&c, 0).unwrap();
        assert_eq!(q.try_dequeue(&c), Some(0));
    }

    #[test]
    fn bulk_dequeue_takes_min_of_available_and_requested() {
        let b = Cuda::new();
        let c = ctx(&b);
        let q = IndexQueue::new(16);
        for v in 0..5 {
            q.try_enqueue(&c, v).unwrap();
        }
        let mut out = Vec::new();
        q.bulk_dequeue(&c, 3, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
        out.clear();
        q.bulk_dequeue(&c, 10, &mut out);
        assert_eq!(out, vec![3, 4]);
        out.clear();
        q.bulk_dequeue(&c, 4, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn bulk_enqueue_respects_capacity() {
        let b = Cuda::new();
        let c = ctx(&b);
        let q = IndexQueue::new(4);
        q.bulk_enqueue(&c, &[1, 2, 3]).unwrap();
        assert_eq!(q.bulk_enqueue(&c, &[4, 5]), Err(AllocError::OutOfMemory));
        assert_eq!(q.len(), 3);
        q.bulk_enqueue(&c, &[4]).unwrap();
        assert_eq!(q.len(), 4);
    }

    #[test]
    fn bulk_uses_fewer_hot_atomics_than_loop() {
        let b = Cuda::new();
        let q = IndexQueue::new(64);
        let c_loop = ctx(&b);
        for v in 0..32 {
            q.try_enqueue(&c_loop, v).unwrap();
        }
        let loop_atomics = {
            let c = ctx(&b);
            for _ in 0..32 {
                q.try_dequeue(&c).unwrap();
            }
            c.events().atomics
        };
        for v in 0..32 {
            q.try_enqueue(&c_loop, v).unwrap();
        }
        let bulk_atomics = {
            let c = ctx(&b);
            let mut out = Vec::new();
            q.bulk_dequeue(&c, 32, &mut out);
            assert_eq!(out.len(), 32);
            c.events().atomics
        };
        assert!(
            bulk_atomics * 4 < loop_atomics,
            "bulk {bulk_atomics} vs loop {loop_atomics}"
        );
    }

    #[test]
    fn concurrent_churn_conserves_values() {
        // 4 threads × enqueue/dequeue churn; multiset of drained values
        // must equal the multiset of enqueued values.
        use std::sync::atomic::AtomicU64;
        let q = std::sync::Arc::new(IndexQueue::new(256));
        let enq_sum = AtomicU64::new(0);
        let deq_sum = AtomicU64::new(0);
        let deq_n = AtomicU32::new(0);
        std::thread::scope(|s| {
            for t in 0..4u32 {
                let q = q.clone();
                let (enq_sum, deq_sum, deq_n) = (&enq_sum, &deq_sum, &deq_n);
                s.spawn(move || {
                    let b = Cuda::new();
                    let c = DevCtx::new(&b, 1000.0, t);
                    for i in 0..500u32 {
                        let v = t * 1000 + i + 1;
                        while q.try_enqueue(&c, v).is_err() {
                            std::thread::yield_now();
                        }
                        enq_sum.fetch_add(v as u64, Ordering::Relaxed);
                        if let Some(got) = q.try_dequeue(&c) {
                            deq_sum.fetch_add(got as u64, Ordering::Relaxed);
                            deq_n.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        // Drain the rest.
        let b = Cuda::new();
        let c = ctx(&b);
        while let Some(v) = q.try_dequeue(&c) {
            deq_sum.fetch_add(v as u64, Ordering::Relaxed);
            deq_n.fetch_add(1, Ordering::Relaxed);
        }
        assert_eq!(deq_n.load(Ordering::Relaxed), 2000);
        assert_eq!(
            enq_sum.load(Ordering::Relaxed),
            deq_sum.load(Ordering::Relaxed)
        );
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn metadata_bytes_scales_with_capacity() {
        assert!(IndexQueue::new(1024).metadata_bytes()
            > IndexQueue::new(16).metadata_bytes());
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(IndexQueue::new(1).capacity(), 1);
        assert_eq!(IndexQueue::new(3).capacity(), 4);
        assert_eq!(IndexQueue::new(4).capacity(), 4);
        assert_eq!(IndexQueue::new(1000).capacity(), 1024);
        // metadata_bytes stays honest about the rounding: it reports the
        // slots actually allocated, not the requested count.
        let q = IndexQueue::new(5);
        assert_eq!(q.capacity(), 8);
        assert_eq!(q.metadata_bytes(), 8 * 4 + 12);
    }

    #[test]
    fn rounded_capacity_is_fully_usable() {
        let b = Cuda::new();
        let c = ctx(&b);
        let q = IndexQueue::new(5); // rounds to 8
        for v in 0..8 {
            q.try_enqueue(&c, v).unwrap();
        }
        assert_eq!(q.try_enqueue(&c, 9), Err(AllocError::OutOfMemory));
        for v in 0..8 {
            assert_eq!(q.try_dequeue(&c), Some(v));
        }
    }

    /// Satellite coverage: the bulk paths only had sequential tests.
    /// 4 threads churn `bulk_enqueue`/`bulk_dequeue` interleaved with
    /// single-op calls; the multiset drained (count + sum + xor of a
    /// value-derived hash) must equal the multiset enqueued, and the
    /// queue must end empty.
    #[test]
    fn concurrent_bulk_churn_conserves_multiset() {
        use std::sync::atomic::AtomicU64;
        let q = std::sync::Arc::new(IndexQueue::new(256));
        let enq = (AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0));
        let deq = (AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0));
        // Order-insensitive multiset fingerprint: count, sum, xor of a
        // mixed hash (xor alone is blind to duplicates, sum alone to
        // swaps).
        fn mix(v: u32) -> u64 {
            let x = v as u64;
            x.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(31)
        }
        let track = |acc: &(AtomicU64, AtomicU64, AtomicU64), v: u32| {
            acc.0.fetch_add(1, Ordering::Relaxed);
            acc.1.fetch_add(v as u64, Ordering::Relaxed);
            acc.2.fetch_xor(mix(v), Ordering::Relaxed);
        };
        std::thread::scope(|s| {
            for t in 0..4u32 {
                let q = q.clone();
                let (enq, deq) = (&enq, &deq);
                s.spawn(move || {
                    let b = Cuda::new();
                    let c = DevCtx::new(&b, 1000.0, t);
                    let mut out = Vec::new();
                    for i in 0..300u32 {
                        // Thread-tagged unique values, never 0-ambiguous.
                        let group: Vec<u32> = (0..(i % 7) + 1)
                            .map(|k| t * 1_000_000 + i * 16 + k + 1)
                            .collect();
                        if i % 3 == 0 {
                            // Single-op path mixed in.
                            for &v in &group {
                                while q.try_enqueue(&c, v).is_err() {
                                    if let Some(got) = q.try_dequeue(&c) {
                                        track(deq, got);
                                    }
                                }
                                track(enq, v);
                            }
                        } else {
                            // All-or-nothing bulk: on OutOfMemory, drain
                            // some room and retry.
                            while q.bulk_enqueue(&c, &group).is_err() {
                                out.clear();
                                q.bulk_dequeue(&c, group.len() as u32, &mut out);
                                for &got in &out {
                                    track(deq, got);
                                }
                                std::thread::yield_now();
                            }
                            for &v in &group {
                                track(enq, v);
                            }
                        }
                        // Dequeue roughly as much as we enqueue so the
                        // queue hovers below capacity.
                        if i % 4 == 3 {
                            if let Some(got) = q.try_dequeue(&c) {
                                track(deq, got);
                            }
                        }
                        out.clear();
                        q.bulk_dequeue(&c, (i % 5) + 1, &mut out);
                        for &got in &out {
                            track(deq, got);
                        }
                    }
                });
            }
        });
        // Drain the remainder single-threaded.
        let b = Cuda::new();
        let c = ctx(&b);
        let mut out = Vec::new();
        loop {
            out.clear();
            q.bulk_dequeue(&c, 32, &mut out);
            if out.is_empty() {
                break;
            }
            for &got in &out {
                track(&deq, got);
            }
        }
        while let Some(got) = q.try_dequeue(&c) {
            track(&deq, got);
        }
        assert_eq!(
            enq.0.load(Ordering::Relaxed),
            deq.0.load(Ordering::Relaxed),
            "enqueue/dequeue op counts diverged"
        );
        assert_eq!(
            enq.1.load(Ordering::Relaxed),
            deq.1.load(Ordering::Relaxed),
            "value sums diverged (loss or duplication)"
        );
        assert_eq!(
            enq.2.load(Ordering::Relaxed),
            deq.2.load(Ordering::Relaxed),
            "multiset fingerprints diverged"
        );
        assert_eq!(q.len(), 0);
    }
}
