//! The pre-allocated device heap: chunk carving, reuse, and the payload
//! data region.
//!
//! The host preallocates one big region (paper §1: "preallocate a chunk
//! of memory on the host to act as a heap"); chunks are carved with a
//! bump pointer and recycled through a reuse queue — freed chunks can be
//! re-owned by *any* size class or become virtual-queue storage, which is
//! the "Ouroboros" self-eating property.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

use crate::simt::{DevCtx, HotSpot};

use super::addr::GlobalAddr;
use super::chunk::{ChunkHeader, STATE_FREE, STATE_OWNED, STATE_QUEUE_STORAGE};
use super::error::AllocError;
use super::index_queue::IndexQueue;
use super::params::{page_size, HeapConfig, CHUNK_SIZE, CHUNK_WORDS};
use super::queue::IdQueue;

/// Heap-level counters (monitoring + EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct HeapStats {
    pub chunks_bumped: AtomicU64,
    pub chunks_reused: AtomicU64,
    pub chunks_released: AtomicU64,
    pub oom_events: AtomicU64,
}

pub struct Heap {
    pub cfg: HeapConfig,
    headers: Vec<ChunkHeader>,
    /// Payload words (None when `cfg.materialise_data` is false).
    data: Option<Vec<AtomicU32>>,
    next_chunk: AtomicU32,
    reuse: IndexQueue,
    hot: HotSpot,
    pub stats: HeapStats,
}

impl Heap {
    pub fn new(cfg: HeapConfig) -> Self {
        let headers = (0..cfg.num_chunks).map(|_| ChunkHeader::default()).collect();
        let data = cfg.materialise_data.then(|| {
            (0..cfg.num_chunks as usize * CHUNK_WORDS)
                .map(|_| AtomicU32::new(0))
                .collect()
        });
        Heap {
            reuse: IndexQueue::new(cfg.num_chunks),
            headers,
            data,
            next_chunk: AtomicU32::new(0),
            hot: HotSpot::new(),
            cfg,
            stats: HeapStats::default(),
        }
    }

    pub fn num_chunks(&self) -> u32 {
        self.cfg.num_chunks
    }

    pub fn header(&self, chunk: u32) -> &ChunkHeader {
        &self.headers[chunk as usize]
    }

    pub fn hot(&self) -> &HotSpot {
        &self.hot
    }

    /// Carve or recycle a chunk. The returned chunk is exclusively owned
    /// by the caller (state still FREE; caller transitions it via
    /// `ChunkHeader::init_for_queue` or `claim_for_queue_storage`).
    pub fn alloc_chunk(&self, ctx: &DevCtx) -> Result<u32, AllocError> {
        // Reuse first: the self-eating property.
        if let Some(c) = self.reuse.try_dequeue(ctx) {
            debug_assert_eq!(self.header(c).state(), STATE_FREE);
            self.stats.chunks_reused.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
            return Ok(c);
        }
        let c = ctx.fetch_add(&self.next_chunk, 1, &self.hot);
        if c >= self.cfg.num_chunks {
            ctx.fetch_sub(&self.next_chunk, 1, &self.hot);
            self.stats.oom_events.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
            return Err(AllocError::OutOfMemory);
        }
        self.stats.chunks_bumped.fetch_add(1, Ordering::Relaxed);
        Ok(c)
    }

    /// Return a chunk to the reuse pool. Caller must hold exclusive
    /// ownership (quiescent sweep, or a drained queue segment).
    pub fn release_chunk(&self, ctx: &DevCtx, chunk: u32) {
        self.header(chunk).set_state(STATE_FREE);
        self.stats.chunks_released.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        // Capacity == num_chunks, so this cannot fail.
        self.reuse
            .try_enqueue(ctx, chunk)
            .expect("heap reuse queue overflow");
    }

    /// Mark a chunk as virtual-queue storage.
    pub fn claim_for_queue_storage(&self, chunk: u32) {
        self.header(chunk).set_state(STATE_QUEUE_STORAGE);
    }

    // ---- payload data region ------------------------------------------------

    #[inline]
    fn data(&self) -> &[AtomicU32] {
        self.data
            .as_deref()
            .expect("heap data region not materialised (HeapConfig)")
    }

    /// Word index of `chunk`'s word `w`.
    #[inline]
    pub fn word_index(chunk: u32, w: usize) -> usize {
        chunk as usize * CHUNK_WORDS + w
    }

    pub fn read_word(&self, ctx: &DevCtx, idx: usize) -> u32 {
        ctx.charge_mem(1);
        // ordering: Acquire; pairs with word store/CAS Release
        self.data()[idx].load(Ordering::Acquire)
    }

    /// Read of a write-hot heap word (virtual-queue front slots).
    pub fn read_word_hot(&self, ctx: &DevCtx, idx: usize, hot: &HotSpot) -> u32 {
        ctx.hot_read(&self.data()[idx], hot)
    }

    pub fn write_word(&self, ctx: &DevCtx, idx: usize, v: u32) {
        ctx.charge_mem(1);
        self.data()[idx].store(v, Ordering::Release); // ordering: Release; device word publish
    }

    /// Atomic swap on a heap word (virtual-queue slot consume).
    pub fn swap_word(&self, ctx: &DevCtx, idx: usize, v: u32, _hot: &HotSpot) -> u32 {
        ctx.charge_mem(1);
        self.data()[idx].swap(v, Ordering::AcqRel) // ordering: AcqRel; claim + publish in one RMW
    }

    /// Atomic CAS on a heap word (virtual-queue slot publish).
    pub fn cas_word(
        &self,
        ctx: &DevCtx,
        idx: usize,
        cur: u32,
        new: u32,
        _hot: &HotSpot,
    ) -> Result<u32, u32> {
        ctx.charge_mem(1);
        // ordering: AcqRel CAS; success publishes, failure observes
        self.data()[idx].compare_exchange(cur, new, Ordering::AcqRel, Ordering::Acquire)
    }

    // ---- address arithmetic ---------------------------------------------------

    /// Byte address of `page` in `chunk` under size class `q`.
    #[inline]
    pub fn addr_of(chunk: u32, q: usize, page: u32) -> u32 {
        chunk * CHUNK_SIZE + page * page_size(q)
    }

    /// Decompose a byte address into (chunk, byte offset).
    #[inline]
    pub fn locate(addr: u32) -> (u32, u32) {
        (addr / CHUNK_SIZE, addr % CHUNK_SIZE)
    }

    /// Validate an address against the heap bounds and its chunk's state.
    pub fn check_addr(&self, addr: u32) -> Result<(u32, u32), AllocError> {
        let (chunk, off) = Self::locate(addr);
        if chunk >= self.cfg.num_chunks {
            return Err(AllocError::InvalidFree(addr));
        }
        let h = self.header(chunk);
        if h.state() != STATE_OWNED {
            return Err(AllocError::InvalidFree(addr));
        }
        let ps = page_size(h.queue());
        if off % ps != 0 {
            return Err(AllocError::InvalidFree(addr));
        }
        Ok((chunk, off / ps))
    }

    /// Strict validation of a device-tagged [`GlobalAddr`] against this
    /// heap, which serves group device `device`: the tag must name this
    /// device and the local part must pass the full [`Heap::check_addr`]
    /// (bounds + chunk ownership state + page alignment). Any failure
    /// is an `InvalidFree` carrying the *global* encoding, so the error
    /// names the device the caller aimed at.
    ///
    /// Note the allocation service's submit-time fast-reject is
    /// deliberately *looser* than this: it checks only the device tag
    /// and chunk bounds (it reads the chunk header anyway for lane
    /// routing) and lets the owning device's free path be the authority
    /// on state/alignment/double-free — this helper is for host-side
    /// callers that want the whole verdict up front.
    pub fn check_addr_global(
        &self,
        device: u32,
        addr: GlobalAddr,
    ) -> Result<(u32, u32), AllocError> {
        if addr.device() != device {
            return Err(AllocError::InvalidFree(addr.raw()));
        }
        self.check_addr(addr.local())
            .map_err(|_| AllocError::InvalidFree(addr.raw()))
    }

    /// Chunks handed out and not yet released (bump high-water minus
    /// reuse pool).
    pub fn live_chunks(&self) -> u32 {
        // ordering: monotonic watermark; scan heuristic
        let bumped = self.next_chunk.load(Ordering::Relaxed).min(self.cfg.num_chunks);
        bumped - self.reuse.len()
    }

    /// Occupancy gauge in `[0, 1]`: the fraction of the heap's chunks
    /// currently handed out ([`Heap::live_chunks`] over the chunk
    /// count). This is the signal capacity-aware placement routes by
    /// (`RoutePolicy::CapacityAware` in the coordinator): it is a racy
    /// relaxed read, cheap enough for the submit hot path, and
    /// monotone-enough under churn for hysteresis to latch on — a
    /// nearly-full member reads close to 1.0 well before its first OOM.
    pub fn occupancy(&self) -> f64 {
        if self.cfg.num_chunks == 0 {
            return 0.0;
        }
        self.live_chunks() as f64 / self.cfg.num_chunks as f64
    }

    /// Copy one allocation's payload from `src` into this heap — the
    /// device-to-device block copy live-set migration is built on. Both
    /// addresses must pass their heap's [`Heap::check_addr`] (owned
    /// chunk, page-aligned) and the two pages must belong to the same
    /// size class; a class mismatch is a migration-plan bug and reports
    /// [`AllocError::QueueCorrupt`]. Returns the number of 32-bit words
    /// copied — 0 when either heap runs without a materialised data
    /// region (queue-throughput configurations), in which case the copy
    /// is a no-op by construction: there is no payload to lose.
    pub fn clone_block(
        &self,
        ctx: &DevCtx,
        src: &Heap,
        src_addr: u32,
        dst_addr: u32,
    ) -> Result<u32, AllocError> {
        let (src_chunk, _) = src.check_addr(src_addr)?;
        let (dst_chunk, _) = self.check_addr(dst_addr)?;
        let q = src.header(src_chunk).queue();
        if self.header(dst_chunk).queue() != q {
            return Err(AllocError::QueueCorrupt);
        }
        if !src.cfg.materialise_data || !self.cfg.materialise_data {
            return Ok(0);
        }
        let words = page_size(q) / 4;
        let src_base = (src_addr / 4) as usize;
        let dst_base = (dst_addr / 4) as usize;
        for w in 0..words as usize {
            let v = src.read_word(ctx, src_base + w);
            self.write_word(ctx, dst_base + w, v);
        }
        Ok(words)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Backend, Cuda};
    use crate::simt::DevCtx;

    fn ctx<'a>(b: &'a dyn Backend) -> DevCtx<'a> {
        DevCtx::new(b, 1000.0, 0)
    }

    fn heap() -> Heap {
        Heap::new(HeapConfig::test_small())
    }

    #[test]
    fn bump_until_oom() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = heap();
        for i in 0..h.num_chunks() {
            assert_eq!(h.alloc_chunk(&c).unwrap(), i);
        }
        assert_eq!(h.alloc_chunk(&c), Err(AllocError::OutOfMemory));
        assert_eq!(h.stats.oom_events.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn release_then_reuse() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = heap();
        let a = h.alloc_chunk(&c).unwrap();
        h.header(a).init_for_queue(&c, 3);
        h.release_chunk(&c, a);
        assert_eq!(h.header(a).state(), STATE_FREE);
        // Reuse pops the released chunk before bumping a new one.
        assert_eq!(h.alloc_chunk(&c).unwrap(), a);
        assert_eq!(h.stats.chunks_reused.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn live_chunks_tracks_churn() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = heap();
        let a = h.alloc_chunk(&c).unwrap();
        let b2 = h.alloc_chunk(&c).unwrap();
        assert_eq!(h.live_chunks(), 2);
        h.release_chunk(&c, a);
        assert_eq!(h.live_chunks(), 1);
        h.release_chunk(&c, b2);
        assert_eq!(h.live_chunks(), 0);
    }

    #[test]
    fn addr_roundtrip() {
        for (chunk, q, page) in [(0u32, 0usize, 0u32), (5, 6, 7), (63, 9, 0)] {
            let addr = Heap::addr_of(chunk, q, page);
            let (c2, off) = Heap::locate(addr);
            assert_eq!(c2, chunk);
            assert_eq!(off, page * page_size(q));
        }
    }

    #[test]
    fn check_addr_rejects_garbage() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = heap();
        // Unowned chunk.
        assert!(h.check_addr(0).is_err());
        let a = h.alloc_chunk(&c).unwrap();
        h.header(a).init_for_queue(&c, 6); // 1 KiB pages
        assert!(h.check_addr(Heap::addr_of(a, 6, 2)).is_ok());
        // Misaligned inside an owned chunk.
        assert!(h.check_addr(Heap::addr_of(a, 6, 2) + 12).is_err());
        // Out of bounds.
        assert!(h.check_addr(u32::MAX - 3).is_err());
    }

    #[test]
    fn check_addr_global_decodes_device_tag() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = heap();
        let a = h.alloc_chunk(&c).unwrap();
        h.header(a).init_for_queue(&c, 6); // 1 KiB pages
        let local = Heap::addr_of(a, 6, 1);
        // The right device tag passes and yields the local decomposition.
        let g = GlobalAddr::new(3, local);
        assert_eq!(h.check_addr_global(3, g), h.check_addr(local));
        // A foreign device tag is rejected with the global encoding.
        assert_eq!(
            h.check_addr_global(2, g),
            Err(AllocError::InvalidFree(g.raw()))
        );
        // A bad local part reports the global encoding too.
        let wild = GlobalAddr::new(3, local + 12);
        assert_eq!(
            h.check_addr_global(3, wild),
            Err(AllocError::InvalidFree(wild.raw()))
        );
    }

    #[test]
    fn occupancy_tracks_live_fraction() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = heap(); // 64 chunks
        assert_eq!(h.occupancy(), 0.0);
        let a = h.alloc_chunk(&c).unwrap();
        let a2 = h.alloc_chunk(&c).unwrap();
        assert!((h.occupancy() - 2.0 / 64.0).abs() < 1e-12);
        h.release_chunk(&c, a);
        assert!((h.occupancy() - 1.0 / 64.0).abs() < 1e-12);
        h.release_chunk(&c, a2);
        assert_eq!(h.occupancy(), 0.0);
    }

    #[test]
    fn clone_block_copies_page_payload_across_heaps() {
        let b = Cuda::new();
        let c = ctx(&b);
        let src = heap();
        let dst = heap();
        let sc = src.alloc_chunk(&c).unwrap();
        src.header(sc).init_for_queue(&c, 6); // 1 KiB pages
        let dc = dst.alloc_chunk(&c).unwrap();
        dst.header(dc).init_for_queue(&c, 6);
        let sa = Heap::addr_of(sc, 6, 3);
        let da = Heap::addr_of(dc, 6, 1);
        for w in 0..256usize {
            src.write_word(&c, (sa / 4) as usize + w, 0xA000 + w as u32);
        }
        assert_eq!(dst.clone_block(&c, &src, sa, da).unwrap(), 256);
        for w in 0..256usize {
            assert_eq!(dst.read_word(&c, (da / 4) as usize + w), 0xA000 + w as u32);
        }
    }

    #[test]
    fn clone_block_rejects_class_mismatch_and_bad_addrs() {
        let b = Cuda::new();
        let c = ctx(&b);
        let src = heap();
        let dst = heap();
        let sc = src.alloc_chunk(&c).unwrap();
        src.header(sc).init_for_queue(&c, 6);
        let dc = dst.alloc_chunk(&c).unwrap();
        dst.header(dc).init_for_queue(&c, 4); // different class
        let sa = Heap::addr_of(sc, 6, 0);
        let da = Heap::addr_of(dc, 4, 0);
        assert_eq!(
            dst.clone_block(&c, &src, sa, da),
            Err(AllocError::QueueCorrupt)
        );
        // Unowned / out-of-bounds source addresses fail validation.
        assert!(matches!(
            dst.clone_block(&c, &src, Heap::addr_of(5, 6, 0), da),
            Err(AllocError::InvalidFree(_))
        ));
    }

    #[test]
    fn data_words_roundtrip() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = heap();
        let idx = Heap::word_index(3, 17);
        h.write_word(&c, idx, 0xDEADBEEF);
        assert_eq!(h.read_word(&c, idx), 0xDEADBEEF);
        assert_eq!(
            h.cas_word(&c, idx, 0xDEADBEEF, 7, h.hot()).unwrap(),
            0xDEADBEEF
        );
        assert_eq!(h.swap_word(&c, idx, 9, h.hot()), 7);
    }
}
