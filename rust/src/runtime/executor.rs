//! PJRT executor: load the AOT HLO-text artifacts once, execute them from
//! the rust hot path. Python never runs here.
//!
//! Pattern follows /opt/xla-example/load_hlo.rs: HLO *text* (not
//! serialized proto — jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects) is parsed by `HloModuleProto::
//! from_text_file`, compiled on the CPU PJRT client, and executed with
//! `Literal` inputs. Lowering used `return_tuple=True`, so outputs are
//! tuples.

use std::path::Path;

use anyhow::{Context, Result};

use super::artifact::Manifest;

/// Outputs of one `workload_step` execution (the benchmark data phase).
#[derive(Debug)]
pub struct TouchOutput {
    /// Full page images, row-major `[touch_pages][page_words]`.
    pub buf: Vec<i32>,
    /// Per-page wrapping-i32 checksums.
    pub checksums: Vec<i32>,
    /// First word of each page (cheap read-back probe).
    pub probe: Vec<i32>,
}

/// Outputs of one `plan_alloc` execution (the batch allocation planner).
#[derive(Debug)]
pub struct PlanOutput {
    /// Size-class queue per request.
    pub queue_idx: Vec<i32>,
    /// First free page per chunk (-1 = full).
    pub first_free: Vec<i32>,
    /// Free pages per chunk.
    pub free_count: Vec<i32>,
}

/// Outputs of one `frag_report` execution (§4.1 fragmentation study).
#[derive(Debug)]
pub struct FragOutput {
    pub free_count: Vec<i32>,
    /// Longest contiguous free-page run per chunk.
    pub longest_run: Vec<i32>,
    /// Fragmentation score in permille (0 = contiguous, ->1000 =
    /// maximally scattered).
    pub frag_score: Vec<i32>,
}

pub struct Runtime {
    client: xla::PjRtClient,
    workload_step: xla::PjRtLoadedExecutable,
    plan_alloc: xla::PjRtLoadedExecutable,
    frag_report: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
}

impl Runtime {
    /// Load and compile both artifacts from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))
        };
        Ok(Runtime {
            workload_step: compile("workload_step")?,
            plan_alloc: compile("plan_alloc")?,
            frag_report: compile("frag_report")?,
            client,
            manifest,
        })
    }

    /// Load from the discovered artifacts directory.
    pub fn load_default() -> Result<Self> {
        let dir = super::artifact::find_artifacts_dir()
            .context("artifacts/ not found — run `make artifacts`")?;
        Self::load(&dir)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute the data phase over exactly `manifest.touch_pages` page
    /// offsets.
    pub fn workload_step(&self, offsets: &[i32], seed: i32) -> Result<TouchOutput> {
        let m = &self.manifest;
        anyhow::ensure!(
            offsets.len() == m.touch_pages as usize,
            "workload_step expects {} offsets, got {}",
            m.touch_pages,
            offsets.len()
        );
        let off = xla::Literal::vec1(offsets);
        let seed = xla::Literal::vec1(&[seed]);
        let result = self.workload_step.execute::<xla::Literal>(&[off, seed])?[0][0]
            .to_literal_sync()?;
        let (buf, cks, probe) = result.to_tuple3()?;
        Ok(TouchOutput {
            buf: buf.to_vec::<i32>()?,
            checksums: cks.to_vec::<i32>()?,
            probe: probe.to_vec::<i32>()?,
        })
    }

    /// Execute the batch allocation planner: `plan_batch` request sizes +
    /// `plan_chunks * bitmap_words` occupancy words.
    pub fn plan_alloc(&self, sizes: &[i32], bitmaps: &[u32]) -> Result<PlanOutput> {
        let m = &self.manifest;
        anyhow::ensure!(
            sizes.len() == m.plan_batch as usize,
            "plan_alloc expects {} sizes, got {}",
            m.plan_batch,
            sizes.len()
        );
        anyhow::ensure!(
            bitmaps.len() == (m.plan_chunks * m.bitmap_words) as usize,
            "plan_alloc expects {}x{} bitmap words",
            m.plan_chunks,
            m.bitmap_words
        );
        let sizes = xla::Literal::vec1(sizes);
        let bm = xla::Literal::vec1(bitmaps)
            .reshape(&[m.plan_chunks as i64, m.bitmap_words as i64])?;
        let result = self.plan_alloc.execute::<xla::Literal>(&[sizes, bm])?[0][0]
            .to_literal_sync()?;
        let (q, ff, fc) = result.to_tuple3()?;
        Ok(PlanOutput {
            queue_idx: q.to_vec::<i32>()?,
            first_free: ff.to_vec::<i32>()?,
            free_count: fc.to_vec::<i32>()?,
        })
    }

    /// Execute the fragmentation-metric kernel over `plan_chunks`
    /// occupancy bitmaps.
    pub fn frag_report(&self, bitmaps: &[u32]) -> Result<FragOutput> {
        let m = &self.manifest;
        anyhow::ensure!(
            bitmaps.len() == (m.plan_chunks * m.bitmap_words) as usize,
            "frag_report expects {}x{} bitmap words",
            m.plan_chunks,
            m.bitmap_words
        );
        let bm = xla::Literal::vec1(bitmaps)
            .reshape(&[m.plan_chunks as i64, m.bitmap_words as i64])?;
        let result = self.frag_report.execute::<xla::Literal>(&[bm])?[0][0]
            .to_literal_sync()?;
        let (free, run, score) = result.to_tuple3()?;
        Ok(FragOutput {
            free_count: free.to_vec::<i32>()?,
            longest_run: run.to_vec::<i32>()?,
            frag_score: score.to_vec::<i32>()?,
        })
    }
}
