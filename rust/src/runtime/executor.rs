//! Artifact executor: run the three AOT-lowered programs (`workload_step`,
//! `plan_alloc`, `frag_report`) from the rust request path.
//!
//! The production configuration executes the HLO-text artifacts through a
//! PJRT client (`xla` crate; see /opt/xla-example for the interchange
//! rationale). The offline build image ships neither that crate nor a
//! registry to fetch it from, so this module provides the **native
//! reference engine**: bit-exact host implementations of the same three
//! programs, mirroring the Pallas kernels word for word —
//!
//! * `workload_step`  ↔ python/compile/kernels/touch_verify.py
//!   (`pattern::expected_word` / `expected_checksum`);
//! * `plan_alloc`     ↔ kernels/size_to_queue.py + bitmap_scan.py
//!   (compare-count binning, popcount free counts, lowest-zero-bit scan);
//! * `frag_report`    ↔ kernels/frag_metric.py
//!   (longest contiguous free run, permille fragmentation score).
//!
//! The python test suite pins the kernels to the same formulas, so the
//! two halves of the system stay in lock-step even without a PJRT
//! round-trip. When an `artifacts/` directory exists its manifest is
//! still loaded and validated against the rust geometry.

use std::path::Path;

use crate::ensure;
use crate::ouroboros::params;
use crate::util::errs::Result;

use super::artifact::{find_artifacts_dir, Manifest};
use super::pattern;

/// Outputs of one `workload_step` execution (the benchmark data phase).
#[derive(Debug)]
pub struct TouchOutput {
    /// Full page images, row-major `[touch_pages][page_words]`.
    pub buf: Vec<i32>,
    /// Per-page wrapping-i32 checksums.
    pub checksums: Vec<i32>,
    /// First word of each page (cheap read-back probe).
    pub probe: Vec<i32>,
}

/// Outputs of one `plan_alloc` execution (the batch allocation planner).
#[derive(Debug)]
pub struct PlanOutput {
    /// Size-class queue per request.
    pub queue_idx: Vec<i32>,
    /// First free page per chunk (-1 = full).
    pub first_free: Vec<i32>,
    /// Free pages per chunk.
    pub free_count: Vec<i32>,
}

/// Outputs of one `frag_report` execution (§4.1 fragmentation study).
#[derive(Debug)]
pub struct FragOutput {
    pub free_count: Vec<i32>,
    /// Longest contiguous free-page run per chunk.
    pub longest_run: Vec<i32>,
    /// Fragmentation score in permille (0 = contiguous, ->1000 =
    /// maximally scattered).
    pub frag_score: Vec<i32>,
}

pub struct Runtime {
    pub manifest: Manifest,
    platform: &'static str,
}

impl Runtime {
    /// Load the manifest from `dir` (validating geometry) and bind the
    /// native engine to its shapes.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { manifest, platform: "native-reference" })
    }

    /// Load from the discovered artifacts directory, or fall back to the
    /// canonical shapes when none exists (the engine needs no artifacts).
    pub fn load_default() -> Result<Self> {
        match find_artifacts_dir() {
            Some(dir) => Self::load(&dir),
            None => Ok(Runtime {
                manifest: Manifest::native_default(),
                platform: "native-reference",
            }),
        }
    }

    pub fn platform(&self) -> String {
        self.platform.to_string()
    }

    /// Execute the data phase over exactly `manifest.touch_pages` page
    /// offsets.
    pub fn workload_step(&self, offsets: &[i32], seed: i32) -> Result<TouchOutput> {
        let m = &self.manifest;
        ensure!(
            offsets.len() == m.touch_pages as usize,
            "workload_step expects {} offsets, got {}",
            m.touch_pages,
            offsets.len()
        );
        let pw = m.page_words as usize;
        let mut buf = vec![0i32; offsets.len() * pw];
        let mut checksums = Vec::with_capacity(offsets.len());
        let mut probe = Vec::with_capacity(offsets.len());
        for (i, &off) in offsets.iter().enumerate() {
            pattern::fill_page(off, seed, &mut buf[i * pw..(i + 1) * pw]);
            checksums.push(pattern::expected_checksum(off, m.page_words, seed));
            probe.push(pattern::expected_word(off, 0, seed));
        }
        Ok(TouchOutput { buf, checksums, probe })
    }

    /// Execute the batch allocation planner: `plan_batch` request sizes +
    /// `plan_chunks * bitmap_words` occupancy words.
    pub fn plan_alloc(&self, sizes: &[i32], bitmaps: &[u32]) -> Result<PlanOutput> {
        let m = &self.manifest;
        ensure!(
            sizes.len() == m.plan_batch as usize,
            "plan_alloc expects {} sizes, got {}",
            m.plan_batch,
            sizes.len()
        );
        ensure!(
            bitmaps.len() == (m.plan_chunks * m.bitmap_words) as usize,
            "plan_alloc expects {}x{} bitmap words",
            m.plan_chunks,
            m.bitmap_words
        );
        let queue_idx = sizes.iter().map(|&s| bin_size(s)).collect();
        let words = m.bitmap_words as usize;
        let mut first_free = Vec::with_capacity(m.plan_chunks as usize);
        let mut free_count = Vec::with_capacity(m.plan_chunks as usize);
        for chunk in bitmaps.chunks_exact(words) {
            let (first, free) = scan_chunk(chunk);
            first_free.push(first);
            free_count.push(free);
        }
        Ok(PlanOutput { queue_idx, first_free, free_count })
    }

    /// Execute the fragmentation-metric kernel over `plan_chunks`
    /// occupancy bitmaps.
    pub fn frag_report(&self, bitmaps: &[u32]) -> Result<FragOutput> {
        let m = &self.manifest;
        ensure!(
            bitmaps.len() == (m.plan_chunks * m.bitmap_words) as usize,
            "frag_report expects {}x{} bitmap words",
            m.plan_chunks,
            m.bitmap_words
        );
        let words = m.bitmap_words as usize;
        let n = m.plan_chunks as usize;
        let mut free_count = Vec::with_capacity(n);
        let mut longest_run = Vec::with_capacity(n);
        let mut frag_score = Vec::with_capacity(n);
        for chunk in bitmaps.chunks_exact(words) {
            let (_, free) = scan_chunk(chunk);
            let run = longest_free_run(chunk);
            // frag_metric.py: score = 1000 - (1000 * run) // max(free, 1),
            // 0 for an empty free set.
            let score = if free > 0 { 1000 - (1000 * run) / free.max(1) } else { 0 };
            free_count.push(free);
            longest_run.push(run);
            frag_score.push(score);
        }
        Ok(FragOutput { free_count, longest_run, frag_score })
    }
}

/// Branchless size→queue binning, mirroring kernels/size_to_queue.py:
/// the queue index is the count of page sizes strictly smaller than the
/// request, clamped to the largest queue.
fn bin_size(s: i32) -> i32 {
    let mut q = 0i32;
    for i in 0..params::NUM_QUEUES - 1 {
        if s > params::page_size(i) as i32 {
            q += 1;
        }
    }
    q
}

/// Lowest zero bit (-1 if full) + zero-bit count over one chunk's bitmap
/// words, mirroring kernels/bitmap_scan.py (bit order: word-major, LSB
/// first — bit `w*32 + b` is page `w*32 + b`).
fn scan_chunk(words: &[u32]) -> (i32, i32) {
    let mut free = 0i32;
    let mut first = -1i32;
    for (w, &word) in words.iter().enumerate() {
        free += word.count_zeros() as i32;
        if first < 0 && word != u32::MAX {
            first = (w as u32 * 32 + (!word).trailing_zeros()) as i32;
        }
    }
    (first, free)
}

/// Longest contiguous run of zero bits across the whole bitmap.
fn longest_free_run(words: &[u32]) -> i32 {
    let (mut best, mut run) = (0i32, 0i32);
    for &word in words {
        for bit in 0..32 {
            if word & (1u32 << bit) == 0 {
                run += 1;
                best = best.max(run);
            } else {
                run = 0;
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_size_matches_queue_for_size_in_range() {
        for s in 1..=params::CHUNK_SIZE {
            assert_eq!(
                bin_size(s as i32),
                params::queue_for_size(s).unwrap() as i32,
                "size {s}"
            );
        }
        // Out-of-range inputs clamp like the Pallas kernel.
        assert_eq!(bin_size(0), 0);
        assert_eq!(bin_size(-5), 0);
        assert_eq!(bin_size(100_000), params::NUM_QUEUES as i32 - 1);
    }

    #[test]
    fn scan_chunk_first_free_and_count() {
        let w = params::BITMAP_WORDS;
        assert_eq!(scan_chunk(&vec![0u32; w]), (0, 512));
        assert_eq!(scan_chunk(&vec![u32::MAX; w]), (-1, 0));
        let mut bm = vec![0u32; w];
        // First 37 pages taken.
        bm[0] = u32::MAX;
        bm[1] = 0b1_1111;
        assert_eq!(scan_chunk(&bm), (37, 512 - 37));
    }

    #[test]
    fn frag_scores_match_pallas_cases() {
        let rt = Runtime::load_default().unwrap();
        let m = rt.manifest.clone();
        let words = m.bitmap_words as usize;
        let mut bitmaps = vec![0u32; m.plan_chunks as usize * words];
        // Chunk 1: alternating bits — 256 free pages, runs of 1.
        bitmaps[words..2 * words].fill(0x5555_5555);
        // Chunk 2: full.
        bitmaps[2 * words..3 * words].fill(u32::MAX);
        let out = rt.frag_report(&bitmaps).unwrap();
        assert_eq!(
            (out.free_count[0], out.longest_run[0], out.frag_score[0]),
            (512, 512, 0)
        );
        assert_eq!(
            (out.free_count[1], out.longest_run[1], out.frag_score[1]),
            (256, 1, 1000 - 1000 / 256)
        );
        assert_eq!(
            (out.free_count[2], out.longest_run[2], out.frag_score[2]),
            (0, 0, 0)
        );
    }

    #[test]
    fn workload_step_shapes_and_values() {
        let rt = Runtime::load_default().unwrap();
        let m = rt.manifest.clone();
        let offsets: Vec<i32> =
            (0..m.touch_pages as i32).map(|i| i * 8192).collect();
        let out = rt.workload_step(&offsets, 9).unwrap();
        assert_eq!(out.buf.len(), (m.touch_pages * m.page_words) as usize);
        let pw = m.page_words as usize;
        for i in [0usize, 13, m.touch_pages as usize - 1] {
            let off = offsets[i];
            assert_eq!(out.probe[i], pattern::expected_word(off, 0, 9));
            assert_eq!(
                out.checksums[i],
                pattern::expected_checksum(off, m.page_words, 9)
            );
            assert_eq!(out.buf[i * pw + 7], pattern::expected_word(off, 7, 9));
        }
        // Wrong shapes rejected.
        assert!(rt.workload_step(&[1, 2, 3], 9).is_err());
    }
}
