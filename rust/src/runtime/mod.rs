//! Artifact runtime for the AOT-compiled JAX/Pallas programs, executed
//! from the rust request path. The offline image has no PJRT (`xla`)
//! crate, so [`executor`] ships a native reference engine mirroring the
//! kernels bit-for-bit; the manifest contract with the python compile
//! path ([`artifact`]) is unchanged.

pub mod artifact;
pub mod executor;
pub mod pattern;

pub use artifact::{find_artifacts_dir, Manifest};
pub use executor::{FragOutput, PlanOutput, Runtime, TouchOutput};
