//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text)
//! and executes them from the rust request path. See DESIGN.md §2 and
//! /opt/xla-example/README.md for the interchange-format rationale.

pub mod artifact;
pub mod executor;
pub mod pattern;

pub use artifact::{find_artifacts_dir, Manifest};
pub use executor::{FragOutput, PlanOutput, Runtime, TouchOutput};
