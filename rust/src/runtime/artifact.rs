//! Artifact discovery + the manifest contract with the python compile
//! path.
//!
//! `python -m compile.aot` writes `manifest.txt` (key=value) alongside the
//! HLO text artifacts; this module parses it and cross-checks the
//! geometry against `ouroboros::params` so the two halves of the system
//! can never silently drift.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::errs::{Context, Result};

use crate::ouroboros::params;

/// Parsed artifacts/manifest.txt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub smallest_page: u32,
    pub num_queues: u32,
    pub chunk_size: u32,
    pub max_pages_per_chunk: u32,
    pub bitmap_words: u32,
    pub plan_batch: u32,
    pub plan_chunks: u32,
    pub touch_pages: u32,
    pub page_words: u32,
    pub mix_a: u32,
    pub mix_b: u32,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut kv = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("malformed manifest line: {line}"))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<u32> {
            kv.get(k)
                .with_context(|| format!("manifest missing key `{k}`"))?
                .parse::<u64>()
                .with_context(|| format!("manifest key `{k}` not an integer"))
                .map(|v| v as u32)
        };
        Ok(Manifest {
            smallest_page: get("smallest_page")?,
            num_queues: get("num_queues")?,
            chunk_size: get("chunk_size")?,
            max_pages_per_chunk: get("max_pages_per_chunk")?,
            bitmap_words: get("bitmap_words")?,
            plan_batch: get("plan_batch")?,
            plan_chunks: get("plan_chunks")?,
            touch_pages: get("touch_pages")?,
            page_words: get("page_words")?,
            mix_a: get("mix_a")?,
            mix_b: get("mix_b")?,
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let m = Manifest::parse(&text)?;
        m.validate()?;
        Ok(m)
    }

    /// The canonical manifest (python/compile/params.py values), used by
    /// the native reference engine when no artifacts directory exists —
    /// the shapes the AOT lowering would have been specialised to.
    pub fn native_default() -> Manifest {
        Manifest {
            smallest_page: params::SMALLEST_PAGE,
            num_queues: params::NUM_QUEUES as u32,
            chunk_size: params::CHUNK_SIZE,
            max_pages_per_chunk: params::MAX_PAGES_PER_CHUNK,
            bitmap_words: params::BITMAP_WORDS as u32,
            plan_batch: 1024,
            plan_chunks: 2048,
            touch_pages: 1024,
            page_words: 256,
            mix_a: super::pattern::MIX_A as u32,
            mix_b: super::pattern::MIX_B as u32,
        }
    }

    /// Cross-check against the rust geometry constants.
    pub fn validate(&self) -> Result<()> {
        if self.smallest_page != params::SMALLEST_PAGE
            || self.num_queues as usize != params::NUM_QUEUES
            || self.chunk_size != params::CHUNK_SIZE
            || self.max_pages_per_chunk != params::MAX_PAGES_PER_CHUNK
            || self.bitmap_words as usize != params::BITMAP_WORDS
        {
            bail!(
                "artifact manifest geometry disagrees with rust \
                 ouroboros::params — rebuild artifacts (`make artifacts`)"
            );
        }
        Ok(())
    }
}

/// Locate the artifacts directory: `$OURO_ARTIFACTS`, then `./artifacts`,
/// then walking up from the current directory (so tests and examples work
/// from any workspace subdirectory).
pub fn find_artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("OURO_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.txt").exists() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.txt").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
# comment
smallest_page=16
num_queues=10
chunk_size=8192
max_pages_per_chunk=512
bitmap_words=16
plan_batch=1024
plan_chunks=2048
touch_pages=1024
page_words=256
mix_a=2654435761
mix_b=2246822519
";

    #[test]
    fn parses_and_validates() {
        let m = Manifest::parse(GOOD).unwrap();
        assert_eq!(m.page_words, 256);
        assert_eq!(m.mix_a, 2654435761);
        m.validate().unwrap();
    }

    #[test]
    fn missing_key_rejected() {
        assert!(Manifest::parse("smallest_page=16\n").is_err());
    }

    #[test]
    fn drifted_geometry_rejected() {
        let bad = GOOD.replace("chunk_size=8192", "chunk_size=4096");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn malformed_line_rejected() {
        assert!(Manifest::parse("nonsense without equals\n").is_err());
    }

    #[test]
    fn native_default_is_valid_and_matches_reference() {
        let m = Manifest::native_default();
        m.validate().unwrap();
        assert_eq!(m, Manifest::parse(GOOD).unwrap());
    }
}
