//! Host-side mirror of the `touch_verify` Pallas pattern.
//!
//! The benchmark's data phase writes `val[p, j] = (off[p] * MIX_A ^ seed)
//! + j * MIX_B` in wrapping i32 arithmetic (python/compile/kernels/
//! touch_verify.py and ref.py implement the same function). The rust side
//! recomputes words and checksums independently, so the XLA output is
//! verified against a second implementation, not against itself.

/// Golden-ratio odd constant (0x9E3779B1) as wrapping i32.
pub const MIX_A: i32 = 0x9E37_79B1_u32 as i32;
/// Murmur3 fmix constant (0x85EBCA77) as wrapping i32.
pub const MIX_B: i32 = 0x85EB_CA77_u32 as i32;

/// Word `j` of the pattern for a page at byte offset `off`.
#[inline]
pub fn expected_word(off: i32, j: i32, seed: i32) -> i32 {
    (off.wrapping_mul(MIX_A) ^ seed).wrapping_add(j.wrapping_mul(MIX_B))
}

/// Wrapping-i32 checksum of the first `words` pattern words.
pub fn expected_checksum(off: i32, words: u32, seed: i32) -> i32 {
    let mut acc = 0i32;
    for j in 0..words as i32 {
        acc = acc.wrapping_add(expected_word(off, j, seed));
    }
    acc
}

/// Fill `out` with the pattern for page `off` (the simulated-write path).
pub fn fill_page(off: i32, seed: i32, out: &mut [i32]) {
    for (j, w) in out.iter_mut().enumerate() {
        *w = expected_word(off, j as i32, seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_match_manifest_values() {
        assert_eq!(MIX_A as u32, 2654435761);
        assert_eq!(MIX_B as u32, 2246822519);
    }

    #[test]
    fn checksum_is_sum_of_words() {
        let off = 0x1234;
        let seed = 77;
        let mut page = [0i32; 64];
        fill_page(off, seed, &mut page);
        let sum = page.iter().fold(0i32, |a, &w| a.wrapping_add(w));
        assert_eq!(sum, expected_checksum(off, 64, seed));
    }

    #[test]
    fn seed_and_offset_change_pattern() {
        assert_ne!(expected_word(1, 0, 9), expected_word(2, 0, 9));
        assert_ne!(expected_word(1, 0, 9), expected_word(1, 0, 10));
        assert_ne!(expected_word(1, 0, 9), expected_word(1, 1, 9));
    }

    #[test]
    fn wrapping_matches_python_reference_values() {
        // Cross-checked against python/tests/test_touch_verify.py's
        // independent numpy model: off=0, seed=0 -> word j = j * MIX_B.
        assert_eq!(expected_word(0, 0, 0), 0);
        assert_eq!(expected_word(0, 1, 0), MIX_B);
        assert_eq!(expected_word(0, 2, 0), MIX_B.wrapping_mul(2));
        // A value that overflows i32 must wrap, not saturate.
        let w = expected_word(i32::MAX, 1000, -1);
        assert_eq!(
            w,
            (i32::MAX.wrapping_mul(MIX_A) ^ -1)
                .wrapping_add(1000i32.wrapping_mul(MIX_B))
        );
    }
}
