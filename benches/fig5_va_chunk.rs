//! Regenerates paper Figure 5 (virtualized-array-chunk allocator): mean subsequent
//! allocation time vs allocation size (left) and vs simultaneous
//! allocations (right), across the toolchain x hardware matrix.
//! Run: `cargo bench --bench fig5_va_chunk` (OURO_BENCH_FULL=1 for the full axes).

#[path = "fig_common/mod.rs"]
mod fig_common;

fn main() {
    fig_common::run(5);
}
