//! L3 hot-path throughput: host-wall malloc/free pairs per second for
//! every allocator variant (single simulated thread). This is the
//! coordinator-side perf budget from DESIGN.md §8: the simulator must
//! sustain >= 1M alloc+free pairs/s so it is never the bottleneck of a
//! figure sweep.
//!
//! Run: `cargo bench --bench alloc_hotpath`

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::ouroboros::{build_allocator, HeapConfig, Variant};
use ouroboros_tpu::simt::DevCtx;
use ouroboros_tpu::util::bench;

const PAIRS: usize = 20_000;

fn main() {
    let b = Cuda::new();
    for v in Variant::all() {
        let alloc = build_allocator(v, &HeapConfig::default());
        let ctx = DevCtx::new(&b, 1455.0, 0);
        // Warm the size class so the steady-state path is measured.
        let warm = alloc.malloc(&ctx, 1000).unwrap();
        alloc.free(&ctx, warm).unwrap();

        let stats = bench::run(1, 5, || {
            for _ in 0..PAIRS {
                let a = alloc.malloc(&ctx, 1000).expect("malloc");
                alloc.free(&ctx, a).expect("free");
            }
        });
        let pairs_per_sec = PAIRS as f64 / stats.median.as_secs_f64();
        bench::report(&format!("alloc_hotpath/{}", v.id()), &stats);
        println!(
            "throughput {}: {:.2}M alloc+free pairs/s (median)",
            v.id(),
            pairs_per_sec / 1e6
        );
    }
}
