//! Baseline comparison: Ouroboros variants vs the 2009-era CUDA-malloc
//! model (global lock + first-fit). Paper §1 motivation: device malloc
//! is "often considered slow and unreliable" — this quantifies the gap
//! on the same simulated device.
//!
//! Run: `cargo bench --bench baseline_system`

use std::sync::Arc;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::ouroboros::{
    allocator::{warp_free, warp_malloc},
    build_allocator, system_alloc::SystemAllocator, HeapConfig, Variant,
};
use ouroboros_tpu::simt::{Device, DeviceProfile, Grid};

fn main() {
    for threads in [128u32, 1024, 4096] {
        // Ouroboros page allocator.
        let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
        let alloc = build_allocator(Variant::Page, &HeapConfig::default());
        let a2 = alloc.clone();
        // warm
        let a3 = a2.clone();
        device.launch("warm", Grid::new(threads), move |w| {
            let lanes: Vec<u32> = w.active_lanes().collect();
            let rs = warp_malloc(a3.as_ref(), w, &vec![1000; lanes.len()]);
            let addrs: Vec<Option<u32>> =
                rs.iter().map(|r| r.as_ref().ok().copied()).collect();
            warp_free(a3.as_ref(), w, &addrs);
        });
        let st = device.launch("ouro", Grid::new(threads), move |w| {
            let lanes: Vec<u32> = w.active_lanes().collect();
            let rs = warp_malloc(a2.as_ref(), w, &vec![1000; lanes.len()]);
            let addrs: Vec<Option<u32>> =
                rs.iter().map(|r| r.as_ref().ok().copied()).collect();
            warp_free(a2.as_ref(), w, &addrs);
        });

        // System (lock + first-fit) baseline on the same device model.
        let device2 = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
        let sys = Arc::new(SystemAllocator::new(64 << 20));
        let sys2 = sys.clone();
        let st_sys = device2.launch("system", Grid::new(threads), move |w| {
            let _p = w.ctx.parallel_lanes(w.lane_count());
            let mut addrs = Vec::new();
            for _lane in w.active_lanes() {
                addrs.push(sys2.malloc(&w.ctx, 1000).expect("sys malloc"));
            }
            for a in addrs {
                sys2.free(&w.ctx, a).expect("sys free");
            }
        });

        println!(
            "baseline threads={threads}: ouroboros-page {:.1} us vs \
             system-malloc {:.1} us  ({:.1}x speedup; {} lock contentions)",
            st.device_us,
            st_sys.device_us,
            st_sys.device_us / st.device_us.max(1e-9),
            sys.lock_contentions.load(std::sync::atomic::Ordering::Relaxed)
        );
    }
    println!(
        "\ninterpretation: the single global lock serializes every \
         operation — the gap widens with thread count, which is the \
         paper's motivation for queue-based allocators."
    );
}
