//! Allocation-service throughput: end-to-end ops/s through the router
//! with concurrent client threads (the L3 coordinator perf target;
//! EXPERIMENTS.md §Perf).
//!
//! Compares the **sharded** service (per-size-class lanes — this PR's
//! deployment shape) against a **single-lane** configuration: the
//! seed's one-batcher/one-worker topology, but running the same new
//! coalesced bulk dispatch (so the row isolates the *sharding* effect;
//! the bulk-path win over the seed's per-op `malloc_step` retries is
//! common to both rows and benches separately via
//! `ablation_coalescing`). The sharded row should pull ahead as clients
//! grow (8+ is the acceptance point), since per-class lanes remove
//! cross-class contention on the batcher lock and the shared queue
//! counters, and let classes progress in parallel.
//!
//! Run: `cargo bench --bench service_throughput`

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::coordinator::stats::render_lane_counts;
use ouroboros_tpu::ouroboros::{build_allocator, HeapConfig, Variant};
use ouroboros_tpu::simt::{Device, DeviceProfile};

const OPS_PER_CLIENT: usize = 2_000;

/// Run one configuration; returns ops/s.
fn run(clients: usize, policy: BatchPolicy, label: &str) -> f64 {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    let alloc = build_allocator(Variant::Page, &HeapConfig::default());
    let service = AllocService::start(device, alloc, policy);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..clients {
            let c = service.client();
            s.spawn(move || {
                for i in 0..OPS_PER_CLIENT {
                    // Sizes sweep several classes so the sharded lanes
                    // actually fan out (64..1063 B -> q2..q7).
                    let a = c.alloc(64 + (i as u32 % 1000)).expect("alloc");
                    c.free(a).expect("free");
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let total_ops = clients * OPS_PER_CLIENT * 2;
    let ops_per_sec = total_ops as f64 / dt;
    let stats = service.stats();
    println!(
        "service_throughput clients={clients} {label}: {:.0} ops/s \
         (mean batch {:.1}, {} batches; lanes {})",
        ops_per_sec,
        stats.mean_batch(),
        stats.batches.load(Ordering::Relaxed),
        render_lane_counts(&stats.lane_batches()),
    );
    drop(service);
    ops_per_sec
}

fn main() {
    for clients in [1usize, 2, 4, 8] {
        let single = run(clients, BatchPolicy::single_lane(), "single-lane");
        let sharded = run(clients, BatchPolicy::default(), "sharded   ");
        println!(
            "  -> sharded/single speedup at {clients} clients: {:.2}x\n",
            sharded / single.max(1e-9)
        );
    }
}
