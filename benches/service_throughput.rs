//! Allocation-service throughput: end-to-end ops/s through the router
//! (the L3 coordinator perf target; EXPERIMENTS.md §Perf).
//!
//! Three comparisons:
//!
//! 1. **Async pipeline vs blocking** (PR 2's row, kept as regression
//!    guard): a *single* client thread drives the same rolling
//!    single-class workload blocking, async at depth 1, and async at
//!    depth 32. The depth-32 row must sustain ≥ 2× the blocking ops/s
//!    with a strictly larger mean device batch.
//! 2. **Sharded vs single-lane** (PR 1's row, kept as regression
//!    guard): blocking clients spread over size classes, per-class
//!    lanes vs the seed's one-batcher topology.
//! 3. **Device-group scaling** (this PR's acceptance row): the same
//!    8-client mixed alloc/free pipeline over a 1-, 2- and 4-device
//!    `DeviceGroup` (round-robin placement). The figure of merit is
//!    **modeled** throughput — ops per modeled device-second, where the
//!    group's makespan is its busiest member (devices run concurrently)
//!    — because host wall time measures the simulator, not the
//!    topology. The 4-device group must sustain ≥ 1.5× the modeled
//!    ops/s of the single device; wall-clock ops/s is reported
//!    alongside, ungated.
//!
//! Emits `BENCH_service_throughput.json` with the async/blocking and
//! group-scaling records so CI and later PRs can diff the numbers.
//!
//! Run: `cargo bench --bench service_throughput`
//! (`OURO_BENCH_SMOKE=1` for the CI smoke run's small iteration counts.)

use std::sync::Arc;
use std::time::Instant;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::driver::{run_group_trace, run_service_trace};
use ouroboros_tpu::coordinator::router::RoutePolicy;
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::coordinator::stats::render_lane_counts;
use ouroboros_tpu::coordinator::workload::{rolling_trace, TraceOp};
use ouroboros_tpu::coordinator::ServiceTraceReport;
use ouroboros_tpu::ouroboros::{
    build_allocator, GlobalAddr, HeapConfig, Variant,
};
use ouroboros_tpu::simt::{Device, DeviceProfile};

fn smoke() -> bool {
    std::env::var("OURO_BENCH_SMOKE").is_ok()
}

fn start_service(policy: BatchPolicy) -> AllocService {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    let alloc = build_allocator(Variant::Page, &HeapConfig::default());
    AllocService::start(device, alloc, policy)
}

/// A group of `devices` homogeneous t2000 members, one heap each,
/// round-robin placement.
fn start_group(devices: usize) -> AllocService {
    AllocService::start_named_group(
        &vec![("t2000", Variant::Page); devices],
        &HeapConfig::default(),
        BatchPolicy::default(),
        RoutePolicy::RoundRobin,
        Arc::new(Cuda::new()),
    )
}

/// One async/blocking comparison row: a single client, a fixed-size
/// (single-class) rolling trace, pipeline depth `depth` (0 = use the
/// blocking wrappers op by op). Returns (ops/s, mean device batch).
fn run_single_client(allocs: usize, depth: usize, label: &str) -> (f64, f64) {
    let service = start_service(BatchPolicy::default());
    let client = service.client();
    // Both rows run the exact same trace; only the submission style
    // (blocking wrapper per op vs pipelined submit/wait) differs.
    let trace = rolling_trace(64, allocs, 1000);
    let (total_ops, dt) = if depth == 0 {
        // Blocking baseline: one round-trip per op.
        let mut addr = vec![None::<GlobalAddr>; 64];
        let t0 = Instant::now();
        let mut ops = 0u64;
        for op in &trace {
            match *op {
                TraceOp::Alloc { slot, size } => {
                    addr[slot] = Some(client.alloc(size).expect("alloc"));
                }
                TraceOp::Free { slot } => {
                    client.free(addr[slot].take().unwrap()).expect("free");
                }
            }
            ops += 1;
        }
        (ops, t0.elapsed().as_secs_f64())
    } else {
        let rep = run_service_trace(&client, &trace, depth).expect("trace");
        assert_eq!(rep.alloc_failures, 0, "bench workload must not OOM");
        (rep.submitted, rep.wall.as_secs_f64())
    };
    let ops_per_sec = total_ops as f64 / dt;
    let snap = service.snapshot();
    println!(
        "service_throughput single-client {label}: {ops_per_sec:.0} ops/s \
         (mean batch {:.2}, mean depth {:.1}, ring hw {})",
        snap.mean_batch,
        snap.mean_depth,
        render_lane_counts(&service.ring_high_water()),
    );
    drop(service);
    (ops_per_sec, snap.mean_batch)
}

/// PR 1's sharding row: `clients` blocking threads over mixed classes.
fn run_multi_client(clients: usize, policy: BatchPolicy, label: &str) -> f64 {
    let ops_per_client = if smoke() { 200 } else { 2_000 };
    let service = start_service(policy);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..clients {
            let c = service.client();
            s.spawn(move || {
                for i in 0..ops_per_client {
                    // Sizes sweep several classes so the sharded lanes
                    // actually fan out (64..1063 B -> q2..q7).
                    let a = c.alloc(64 + (i as u32 % 1000)).expect("alloc");
                    c.free(a).expect("free");
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let total_ops = clients * ops_per_client * 2;
    let ops_per_sec = total_ops as f64 / dt;
    let snap = service.snapshot();
    println!(
        "service_throughput clients={clients} {label}: {:.0} ops/s \
         (mean batch {:.1}, {} batches; lanes {})",
        ops_per_sec,
        snap.mean_batch,
        snap.batches,
        render_lane_counts(&snap.lane_batches),
    );
    drop(service);
    ops_per_sec
}

/// Device-group scaling row: `clients` pipelined clients over a
/// `devices`-member group. Returns (wall ops/s, modeled ops/s).
fn run_group(devices: usize, clients: usize, allocs: usize) -> (f64, f64) {
    let service = start_group(devices);
    let trace = rolling_trace(64, allocs, 1000);
    let t0 = Instant::now();
    let reps =
        run_group_trace(&service, clients, &trace, 32).expect("group trace");
    let dt = t0.elapsed().as_secs_f64();
    let agg = ServiceTraceReport::merged(&reps);
    assert_eq!(agg.alloc_failures, 0, "group workload must not OOM");
    let wall_ops = agg.submitted as f64 / dt;
    let snap = service.snapshot();
    let modeled_ops = snap.modeled_ops_per_sec();
    let per_device: Vec<String> = snap
        .devices
        .iter()
        .map(|d| format!("{}:{} ops/{:.0}us", d.name, d.ops, d.device_us))
        .collect();
    println!(
        "service_throughput group devices={devices} clients={clients}: \
         {wall_ops:.0} ops/s wall, {modeled_ops:.0} ops/s modeled \
         (makespan {:.0}us; {})",
        snap.modeled_makespan_us(),
        per_device.join(" "),
    );
    drop(service);
    (wall_ops, modeled_ops)
}

fn main() {
    let allocs = if smoke() { 500 } else { 5_000 };

    // ---- async pipeline vs blocking (single client) ----------------------
    let (blocking, blocking_batch) = run_single_client(allocs, 0, "blocking   ");
    let (depth1, _) = run_single_client(allocs, 1, "async d=1  ");
    let (depth32, depth32_batch) = run_single_client(allocs, 32, "async d=32 ");
    let speedup = depth32 / blocking.max(1e-9);
    println!(
        "  -> async depth=32 vs blocking: {speedup:.2}x \
         (mean batch {depth32_batch:.2} vs {blocking_batch:.2})\n"
    );

    // ---- device-group scaling (8 pipelined clients, this PR's row) -------
    let group_clients = 8usize;
    let group_allocs = if smoke() { 150 } else { 1_000 };
    let (wall1, modeled1) = run_group(1, group_clients, group_allocs);
    let (wall2, modeled2) = run_group(2, group_clients, group_allocs);
    let (wall4, modeled4) = run_group(4, group_clients, group_allocs);
    let group_speedup_modeled = modeled4 / modeled1.max(1e-9);
    let group_speedup_wall = wall4 / wall1.max(1e-9);
    println!(
        "  -> 4-device group vs single device: {group_speedup_modeled:.2}x \
         modeled, {group_speedup_wall:.2}x wall\n"
    );

    let json = format!(
        "{{\n  \"bench\": \"service_throughput\",\n  \
         \"workload\": \"single client, rolling 1000 B trace, {allocs} allocs\",\n  \
         \"blocking_ops_per_sec\": {blocking:.1},\n  \
         \"blocking_mean_batch\": {blocking_batch:.3},\n  \
         \"async_depth1_ops_per_sec\": {depth1:.1},\n  \
         \"async_depth32_ops_per_sec\": {depth32:.1},\n  \
         \"async_depth32_mean_batch\": {depth32_batch:.3},\n  \
         \"speedup_depth32_vs_blocking\": {speedup:.3},\n  \
         \"group_workload\": \"{group_clients} clients, depth-32 rolling \
         1000 B trace, {group_allocs} allocs each, round-robin\",\n  \
         \"group_devices1_ops_per_sec\": {wall1:.1},\n  \
         \"group_devices2_ops_per_sec\": {wall2:.1},\n  \
         \"group_devices4_ops_per_sec\": {wall4:.1},\n  \
         \"group_devices1_modeled_ops_per_sec\": {modeled1:.1},\n  \
         \"group_devices2_modeled_ops_per_sec\": {modeled2:.1},\n  \
         \"group_devices4_modeled_ops_per_sec\": {modeled4:.1},\n  \
         \"group_speedup_4v1_modeled\": {group_speedup_modeled:.3},\n  \
         \"group_speedup_4v1_wall\": {group_speedup_wall:.3}\n}}\n"
    );
    match std::fs::write("BENCH_service_throughput.json", &json) {
        Ok(()) => println!("wrote BENCH_service_throughput.json:\n{json}"),
        Err(e) => eprintln!("could not write perf record: {e}"),
    }

    // Acceptance gates (ISSUE 2): the pipeline must actually pay off.
    assert!(
        speedup >= 2.0,
        "async depth=32 must sustain >= 2x blocking ({depth32:.0} vs \
         {blocking:.0} ops/s)"
    );
    assert!(
        depth32_batch > blocking_batch,
        "async mean batch ({depth32_batch:.2}) must exceed blocking \
         ({blocking_batch:.2})"
    );

    // Acceptance gate (ISSUE 3): the 4-device topology must scale.
    assert!(
        group_speedup_modeled >= 1.5,
        "4-device group must sustain >= 1.5x single-device modeled ops/s \
         ({modeled4:.0} vs {modeled1:.0})"
    );

    // ---- sharded vs single-lane (multi-client, PR 1 row) -----------------
    for clients in [1usize, 2, 4, 8] {
        let single =
            run_multi_client(clients, BatchPolicy::single_lane(), "single-lane");
        let sharded =
            run_multi_client(clients, BatchPolicy::default(), "sharded   ");
        println!(
            "  -> sharded/single speedup at {clients} clients: {:.2}x\n",
            sharded / single.max(1e-9)
        );
    }
}
