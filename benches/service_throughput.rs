//! Allocation-service throughput: end-to-end ops/s through the router +
//! warp-shaped batcher with concurrent client threads (the L3
//! coordinator perf target; EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench service_throughput`

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::ouroboros::{build_allocator, HeapConfig, Variant};
use ouroboros_tpu::simt::{Device, DeviceProfile};

const OPS_PER_CLIENT: usize = 2_000;

fn main() {
    for clients in [1usize, 2, 4, 8] {
        let device =
            Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
        let alloc = build_allocator(Variant::Page, &HeapConfig::default());
        let service =
            AllocService::start(device, alloc, BatchPolicy::default());
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..clients {
                let c = service.client();
                s.spawn(move || {
                    for i in 0..OPS_PER_CLIENT {
                        let a = c.alloc(64 + (i as u32 % 1000)).expect("alloc");
                        c.free(a).expect("free");
                    }
                });
            }
        });
        let dt = t0.elapsed().as_secs_f64();
        let total_ops = clients * OPS_PER_CLIENT * 2;
        let stats = service.stats();
        println!(
            "service_throughput clients={clients}: {:.0} ops/s \
             (mean batch {:.1}, {} batches)",
            total_ops as f64 / dt,
            stats.mean_batch(),
            stats.batches.load(Ordering::Relaxed),
        );
        drop(service);
    }
}
