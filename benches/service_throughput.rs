//! Allocation-service throughput: end-to-end ops/s through the router
//! (the L3 coordinator perf target; EXPERIMENTS.md §Perf).
//!
//! Three comparisons:
//!
//! 1. **Async pipeline vs blocking** (PR 2's row, kept as regression
//!    guard): a *single* client thread drives the same rolling
//!    single-class workload blocking, async at depth 1, and async at
//!    depth 32. The depth-32 row must sustain ≥ 2× the blocking ops/s
//!    with a strictly larger mean device batch.
//! 2. **Sharded vs single-lane** (PR 1's row, kept as regression
//!    guard): blocking clients spread over size classes, per-class
//!    lanes vs the seed's one-batcher topology.
//! 3. **Device-group scaling** (this PR's acceptance row): the same
//!    8-client mixed alloc/free pipeline over a 1-, 2- and 4-device
//!    `DeviceGroup` (round-robin placement). The figure of merit is
//!    **modeled** throughput — ops per modeled device-second, where the
//!    group's makespan is its busiest member (devices run concurrently)
//!    — because host wall time measures the simulator, not the
//!    topology. The 4-device group must sustain ≥ 1.5× the modeled
//!    ops/s of the single device; wall-clock ops/s is reported
//!    alongside, ungated.
//!
//! Emits `BENCH_service_throughput.json` with the async/blocking and
//! group-scaling records so CI and later PRs can diff the numbers.
//!
//! Run: `cargo bench --bench service_throughput`
//! (`OURO_BENCH_SMOKE=1` for the CI smoke run's small iteration counts.)

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use ouroboros_tpu::backend::{Cuda, SyclOneapiNv};
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::driver::{
    failover_quiesce_timeout, run_cached_trace, run_failover_trace,
    run_federation_trace, run_group_trace, run_selfheal_trace,
    run_service_trace,
};
use ouroboros_tpu::coordinator::federation::FederationRouter;
use ouroboros_tpu::coordinator::router::RoutePolicy;
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::coordinator::stats::render_lane_counts;
use ouroboros_tpu::coordinator::workload::{
    churn_trace, rolling_trace, TraceOp,
};
use ouroboros_tpu::coordinator::{
    DrainPacing, HealthEventKind, HealthPolicy, ServiceTraceReport,
    StatsSnapshot,
};
use ouroboros_tpu::ouroboros::{
    build_allocator, GlobalAddr, HeapConfig, Variant,
};
use ouroboros_tpu::simt::{Device, DeviceProfile};

fn smoke() -> bool {
    std::env::var("OURO_BENCH_SMOKE").is_ok()
}

fn start_service(policy: BatchPolicy) -> AllocService {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    let alloc = build_allocator(Variant::Page, &HeapConfig::default());
    AllocService::start(device, alloc, policy)
}

/// A group of `devices` homogeneous t2000 members, one heap each,
/// round-robin placement.
fn start_group(devices: usize) -> AllocService {
    AllocService::start_named_group(
        &vec![("t2000", Variant::Page); devices],
        &HeapConfig::default(),
        BatchPolicy::default(),
        RoutePolicy::RoundRobin,
        Arc::new(Cuda::new()),
    )
}

/// One async/blocking comparison row: a single client, a fixed-size
/// (single-class) rolling trace, pipeline depth `depth` (0 = use the
/// blocking wrappers op by op). Returns (ops/s, mean device batch).
fn run_single_client(allocs: usize, depth: usize, label: &str) -> (f64, f64) {
    let service = start_service(BatchPolicy::default());
    let client = service.client();
    // Both rows run the exact same trace; only the submission style
    // (blocking wrapper per op vs pipelined submit/wait) differs.
    let trace = rolling_trace(64, allocs, 1000);
    let (total_ops, dt) = if depth == 0 {
        // Blocking baseline: one round-trip per op.
        let mut addr = vec![None::<GlobalAddr>; 64];
        let t0 = Instant::now();
        let mut ops = 0u64;
        for op in &trace {
            match *op {
                TraceOp::Alloc { slot, size } => {
                    addr[slot] = Some(client.alloc(size).expect("alloc"));
                }
                TraceOp::Free { slot } => {
                    client.free(addr[slot].take().unwrap()).expect("free");
                }
            }
            ops += 1;
        }
        (ops, t0.elapsed().as_secs_f64())
    } else {
        let rep = run_service_trace(&client, &trace, depth).expect("trace");
        assert_eq!(rep.alloc_failures, 0, "bench workload must not OOM");
        (rep.submitted, rep.wall.as_secs_f64())
    };
    let ops_per_sec = total_ops as f64 / dt;
    let snap = service.snapshot();
    println!(
        "service_throughput single-client {label}: {ops_per_sec:.0} ops/s \
         (mean batch {:.2}, mean depth {:.1}, ring hw {})",
        snap.mean_batch,
        snap.mean_depth,
        render_lane_counts(&service.ring_high_water()),
    );
    drop(service);
    (ops_per_sec, snap.mean_batch)
}

/// ISSUE 8's tentpole comparison, both legs on one service: the same
/// rolling single-class trace driven (a) async at depth 32 through the
/// ticket rings and (b) blocking through the client-side lease cache,
/// where every op is a local free-list hit and only the span mints and
/// returns cross a ring. Returns (cached ops/s, ring ops/s, final
/// snapshot — it carries the per-op latency histograms of both paths).
fn run_cached_pair(allocs: usize) -> (f64, f64, StatsSnapshot) {
    let service = start_service(BatchPolicy::default());
    let trace = rolling_trace(64, allocs, 1000);
    let ring_client = service.client();
    let ring_rep =
        run_service_trace(&ring_client, &trace, 32).expect("ring leg");
    assert_eq!(ring_rep.alloc_failures, 0, "bench workload must not OOM");
    let ring_ops = ring_rep.submitted as f64 / ring_rep.wall.as_secs_f64();
    let cached_client = service.client();
    let rep = run_cached_trace(&cached_client, &trace).expect("cached leg");
    assert_eq!(rep.alloc_failures, 0, "bench workload must not OOM");
    let cached_ops = rep.submitted as f64 / rep.wall.as_secs_f64();
    let snap = service.snapshot();
    println!(
        "service_throughput cached single-client: {cached_ops:.0} ops/s \
         vs {ring_ops:.0} ring depth-32 ({:.2}x; {} mints, {} returns; \
         p99 {:.1}us cached vs {:.1}us ring)",
        cached_ops / ring_ops.max(1e-9),
        snap.lease_mints,
        snap.lease_returns,
        snap.cached_latency.p99_us,
        snap.ring_latency.p99_us,
    );
    drop(service);
    (cached_ops, ring_ops, snap)
}

/// The contended leg of ISSUE 8: 8 blocking clients — 4 with the lease
/// cache armed, 4 ring-only — churn one shared pool of cacheable
/// blocks, so cached blocks are routinely freed by handles that do not
/// own the lease and ride the mimalloc-style delayed-free lists.
/// Returns (wall ops/s, delayed frees observed).
fn run_cached_mixed(ops_per_client: usize) -> (f64, u64) {
    let service = start_service(BatchPolicy::default());
    let pool: Mutex<VecDeque<GlobalAddr>> = Mutex::new(VecDeque::new());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..8usize {
            let c = service.client();
            if t % 2 == 0 {
                c.set_caching(true);
            }
            let pool = &pool;
            s.spawn(move || {
                for i in 0..ops_per_client {
                    // 64..1063 B -> q2..q7, all cacheable classes.
                    let a = c.alloc(64 + (i as u32 % 1000)).expect("alloc");
                    pool.lock().unwrap().push_back(a);
                    // Free the oldest pooled block, but keep a window
                    // live so pops usually land on somebody else's
                    // block and cached frees cross handles.
                    let b = {
                        let mut g = pool.lock().unwrap();
                        if g.len() > 16 {
                            g.pop_front()
                        } else {
                            None
                        }
                    };
                    if let Some(b) = b {
                        c.free(b).expect("free");
                    }
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    // Drain the window through a fresh ring-only handle: the last free
    // of each surrendered lease returns its span.
    let drainer = service.client();
    for a in std::mem::take(&mut *pool.lock().unwrap()) {
        drainer.free(a).expect("drain free");
    }
    assert_eq!(service.live_leases(), 0, "every lease must come home");
    let snap = service.snapshot();
    let ops = (8 * ops_per_client * 2) as f64 / dt;
    println!(
        "service_throughput cached mixed 8-client: {ops:.0} ops/s \
         ({} cached allocs, {} delayed frees, {} mints)",
        snap.cached_allocs, snap.delayed_frees, snap.lease_mints,
    );
    drop(service);
    (ops, snap.delayed_frees)
}

/// ISSUE 9's acceptance row: the same 8-client depth-32 churn — a
/// single size class, so every client contends on one lane — with the
/// EVENT_IDX notification discipline armed vs the eager baseline
/// (`BatchPolicy::eager_notify`). Figure of merit: condvar notifies
/// actually issued per op (ring broadcasts + batcher doorbells rung),
/// plus the ring-path p99 under load — suppression must coalesce the
/// wakeup storm without adding reap latency. Returns (wall ops/s,
/// modeled ops/s, wakeups/op, ring p99 µs, final snapshot).
fn run_wakeup_churn(
    eager: bool,
    clients: usize,
    allocs: usize,
) -> (f64, f64, f64, f64, StatsSnapshot) {
    let service = start_service(BatchPolicy {
        eager_notify: eager,
        ..BatchPolicy::default()
    });
    let trace = rolling_trace(64, allocs, 1000);
    let submitted = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..clients {
            let c = service.client();
            let (trace, submitted) = (&trace, &submitted);
            s.spawn(move || {
                let rep =
                    run_service_trace(&c, trace, 32).expect("wakeup churn");
                assert_eq!(
                    rep.alloc_failures, 0,
                    "bench workload must not OOM"
                );
                submitted.fetch_add(rep.submitted, Ordering::Relaxed);
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let snap = service.snapshot();
    let total_ops = submitted.load(Ordering::Relaxed) as f64;
    let wall = total_ops / dt;
    let modeled = snap.modeled_ops_per_sec();
    let rung = (snap.wakeup_delivered + snap.doorbell_delivered) as f64;
    let per_op = rung / total_ops.max(1.0);
    let label = if eager { "eager     " } else { "suppressed" };
    println!(
        "service_throughput wakeups {label}: {wall:.0} ops/s wall, \
         {modeled:.0} modeled; {per_op:.3} wakeups/op ({} broadcasts + \
         {} doorbells rung, {} + {} elided; ring p99 {:.1}us loaded)",
        snap.wakeup_delivered,
        snap.doorbell_delivered,
        snap.wakeup_suppressed,
        snap.doorbell_suppressed,
        snap.ring_latency.p99_us,
    );
    drop(service);
    (wall, modeled, per_op, snap.ring_latency.p99_us, snap)
}

/// PR 1's sharding row: `clients` blocking threads over mixed classes.
fn run_multi_client(clients: usize, policy: BatchPolicy, label: &str) -> f64 {
    let ops_per_client = if smoke() { 200 } else { 2_000 };
    let service = start_service(policy);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..clients {
            let c = service.client();
            s.spawn(move || {
                for i in 0..ops_per_client {
                    // Sizes sweep several classes so the sharded lanes
                    // actually fan out (64..1063 B -> q2..q7).
                    let a = c.alloc(64 + (i as u32 % 1000)).expect("alloc");
                    c.free(a).expect("free");
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let total_ops = clients * ops_per_client * 2;
    let ops_per_sec = total_ops as f64 / dt;
    let snap = service.snapshot();
    println!(
        "service_throughput clients={clients} {label}: {:.0} ops/s \
         (mean batch {:.1}, {} batches; lanes {})",
        ops_per_sec,
        snap.mean_batch,
        snap.batches,
        render_lane_counts(&snap.lane_batches),
    );
    drop(service);
    ops_per_sec
}

/// Capacity sweep: a skewed group — one *small and slow* member (64
/// chunks, low-power profile on the SYCL-NV toolchain) next to two big
/// fast ones (512 chunks, CUDA) — rammed with an alloc-only 1000 B
/// load until the first OOM (or the quota). Occupancy-blind round-robin
/// keeps feeding the small member a third of the load and hits its OOM
/// wall early, with the slow member as the makespan; capacity-aware
/// placement sheds it before the wall and water-fills the fast pair.
/// Figure of merit: successful allocs per modeled second **before the
/// first OOM** (makespan = busiest member at stop).
fn run_capacity(route: RoutePolicy, quota: u64) -> (f64, u64, u64) {
    let lp = DeviceProfile {
        name: "t2000-lp",
        sms: 8,
        warps_per_sm: 32,
        warp_width: 32,
        clock_mhz: 728.0,
    };
    let small = HeapConfig { num_chunks: 64, ..HeapConfig::default() };
    let big = HeapConfig { num_chunks: 512, ..HeapConfig::default() };
    let members = vec![
        (
            Device::new(lp, Arc::new(SyclOneapiNv::new())),
            build_allocator(Variant::Page, &small),
        ),
        (
            Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
            build_allocator(Variant::Page, &big),
        ),
        (
            Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
            build_allocator(Variant::Page, &big),
        ),
    ];
    let service =
        AllocService::start_group(members, BatchPolicy::default(), route);
    let stop = AtomicBool::new(false);
    let ok = AtomicU64::new(0);
    let failures = AtomicU64::new(0);
    let clients = 4u64;
    std::thread::scope(|s| {
        for _ in 0..clients {
            let c = service.client();
            let (stop, ok, failures) = (&stop, &ok, &failures);
            s.spawn(move || {
                for _ in 0..quota / clients {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    match c.alloc(1000) {
                        Ok(_) => {
                            ok.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            failures.fetch_add(1, Ordering::Relaxed);
                            stop.store(true, Ordering::Relaxed);
                            break;
                        }
                    }
                }
            });
        }
    });
    let snap = service.snapshot();
    let ok = ok.load(Ordering::Relaxed);
    let failures = failures.load(Ordering::Relaxed);
    let makespan = snap.modeled_makespan_us();
    let modeled = if makespan > 0.0 { ok as f64 / makespan * 1e6 } else { 0.0 };
    let occ: Vec<String> = snap
        .devices
        .iter()
        .map(|d| format!("{}:{:.0}%", d.name, d.heap_occupancy * 100.0))
        .collect();
    println!(
        "service_throughput capacity {}: {ok} allocs before first OOM \
         ({failures} failures, {modeled:.0} ops/s modeled, makespan \
         {makespan:.0}us; {})",
        route.id(),
        occ.join(" "),
    );
    drop(service);
    (modeled, ok, failures)
}

/// Failover row: 8 pipelined clients churn over a 3-device group while
/// member 1 is drained (live set migrated) and retired mid-trace.
/// Returns (modeled ops/s, migrated, forwarded, skipped, retired_ops).
fn run_failover(allocs: usize) -> (f64, u64, u64, u64, u64) {
    let service = AllocService::start_named_group(
        &[("t2000", Variant::Page); 3],
        &HeapConfig { num_chunks: 512, ..HeapConfig::default() },
        BatchPolicy::default(),
        RoutePolicy::RoundRobin,
        Arc::new(Cuda::new()),
    );
    let trace = rolling_trace(64, allocs, 1000);
    let total_ops = (trace.len() * 8) as u64;
    let reps = run_failover_trace(&service, 8, &trace, 32, 1, total_ops / 4)
        .expect("failover trace");
    let agg = ServiceTraceReport::merged(&reps.reports);
    assert_eq!(agg.alloc_failures, 0, "failover workload must not OOM");
    assert_eq!(
        agg.retired_ops, 0,
        "drain+quiesce+retire must not lose in-flight ops"
    );
    assert_eq!(reps.drain.failed, 0, "live set must be fully rehomed");
    assert_eq!(
        reps.drain.unquiesced, 0,
        "drain must not proceed past in-flight allocs"
    );
    let snap = service.snapshot();
    let modeled = snap.modeled_ops_per_sec();
    let stats = service.stats();
    let forwarded = stats.forwarded_frees.load(Ordering::Relaxed);
    let retired = stats.retired_ops.load(Ordering::Relaxed);
    let migrated = reps.drain.migrated.len() as u64;
    let skipped = reps.drain.skipped_freed;
    println!(
        "service_throughput failover: {migrated} migrated, {forwarded} \
         stale frees forwarded, {skipped} claimed by racing frees, \
         {retired} retired in-flight, {modeled:.0} ops/s modeled \
         (victim state: {})",
        snap.devices[1].state,
    );
    drop(service);
    (modeled, migrated, forwarded, skipped, retired)
}

/// Federation spillover row: `clients` blocking churn threads over a
/// `FederationRouter`. `spill == false` is the baseline — one 2-member
/// group, every placement primary-local. `spill == true` fronts two
/// such groups at quorum 2 and hard-retires one member of group 0
/// before traffic, so the primary is latched away and every
/// primary-0 placement takes the latch-skip + cross-group path; the
/// serving capacity (one healthy 2-member group) matches the baseline,
/// isolating the federation layer's routing cost. Returns
/// (wall ops/s, modeled ops/s, spilled allocs, cross-group frees).
fn run_federation_churn(
    spill: bool,
    clients: usize,
    ops_per_client: usize,
) -> (f64, f64, u64, u64) {
    let mk = || {
        AllocService::start_named_group(
            &[("t2000", Variant::Page); 2],
            &HeapConfig { num_chunks: 512, ..HeapConfig::default() },
            BatchPolicy::default(),
            RoutePolicy::RoundRobin,
            Arc::new(Cuda::new()),
        )
    };
    let fed = if spill {
        FederationRouter::new(vec![mk(), mk()], 2)
    } else {
        FederationRouter::new(vec![mk()], 1)
    };
    if spill {
        // Lose quorum on the primary before traffic starts: every
        // placement must skip the latched group and land cross-group.
        fed.with_group(0, |svc| {
            svc.retire_device(0);
        })
        .unwrap();
        fed.poll_health();
        assert!(fed.is_spilled(0), "quorum loss must latch the primary");
    }
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..clients {
            let c = fed.client();
            s.spawn(move || {
                let mut live: VecDeque<GlobalAddr> = VecDeque::new();
                for i in 0..ops_per_client {
                    // Same class sweep as the sharding row (q2..q7).
                    let size = 64 + ((t * 131 + i) as u32 % 1000);
                    let a = c.alloc(size).expect("federated alloc");
                    live.push_back(a);
                    if live.len() > 32 {
                        c.free(live.pop_front().unwrap()).expect("free");
                    }
                }
                for a in live {
                    c.free(a).expect("drain free");
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let total_ops = (clients * ops_per_client * 2) as u64;
    let wall = total_ops as f64 / dt;
    // Modeled figure: the federation's makespan is its busiest group
    // (groups run concurrently, a group's makespan its busiest member).
    let mut makespan = 0.0f64;
    for g in 0..fed.group_count() {
        if let Some(m) =
            fed.with_group(g, |svc| svc.snapshot().modeled_makespan_us())
        {
            makespan = makespan.max(m);
        }
    }
    let modeled = if makespan > 0.0 {
        total_ops as f64 / makespan * 1e6
    } else {
        0.0
    };
    let stats = fed.stats();
    let label = if spill { "spillover" } else { "baseline " };
    println!(
        "service_throughput federation {label}: {wall:.0} ops/s wall, \
         {modeled:.0} modeled ({} spilled allocs, {} cross-group frees, \
         {} spill events)",
        stats.spilled_allocs, stats.cross_group_frees, stats.spill_events,
    );
    fed.shutdown();
    (wall, modeled, stats.spilled_allocs, stats.cross_group_frees)
}

/// Federation restart row: 4 clients churn a two-group federation
/// through `run_federation_trace`, which kills group 0 mid-trace and
/// restores it from its durable `OUROSNAP` handoff while traffic keeps
/// flowing. Figure of merit: the wall time traffic was barriered at
/// the slot lock (prepare-handoff + wire-format round-trip + rebuild).
/// Returns (recovery µs, lost blocks, leftover swept).
fn run_federation_restart(allocs: usize) -> (u64, u64, u64) {
    let mk = || {
        AllocService::start_named_group(
            &[("t2000", Variant::Page); 2],
            &HeapConfig { num_chunks: 512, ..HeapConfig::default() },
            BatchPolicy::default(),
            RoutePolicy::RoundRobin,
            Arc::new(Cuda::new()),
        )
    };
    let fed = FederationRouter::new(vec![mk(), mk()], 1);
    let trace = churn_trace(0xFED7, 64, allocs, 4096);
    // Kill group 0 once roughly a quarter of the federated ops landed.
    let after = trace.len() as u64;
    let rep = run_federation_trace(&fed, 4, &trace, 0, after)
        .expect("federation trace");
    let agg = ServiceTraceReport::merged(&rep.reports);
    assert_eq!(
        rep.lost_blocks, 0,
        "restart must not lose a single live block"
    );
    assert_eq!(rep.fed_stats.restarts, 1, "exactly one kill+restore");
    assert_eq!(
        agg.retired_ops, 0,
        "the restart must be invisible to federated clients"
    );
    println!(
        "service_throughput federation restart: recovered in {}us \
         ({} leftover blocks swept clean after the trace)",
        rep.restart_us, rep.leftover,
    );
    fed.shutdown();
    (rep.restart_us, rep.lost_blocks, rep.leftover)
}

fn percentile(mut xs: Vec<f64>, p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((xs.len() - 1) as f64 * p).round() as usize;
    xs[idx]
}

/// Self-heal pacing row: 6 blocking churn clients run while member 1's
/// live set is drained out from under them — stop-the-world sweep vs
/// paced ticks. Figure of merit: **modeled ops/s during the drain
/// window** vs the steady-state window right before it (paced draining
/// must not crater client throughput), plus the client-visible p99
/// blocking-alloc latency inside the window. Returns
/// (steady modeled, during modeled, p99 alloc µs, migrated).
fn run_selfheal_pacing(paced: bool) -> (f64, f64, f64, u64) {
    let service = AllocService::start_named_group(
        &[("t2000", Variant::Page); 3],
        &HeapConfig { num_chunks: 512, ..HeapConfig::default() },
        BatchPolicy::default(),
        RoutePolicy::RoundRobin,
        Arc::new(Cuda::new()),
    );
    service.set_forwarding_grace(Duration::from_secs(120));
    let stop = AtomicBool::new(false);
    // 0 = warmup (discarded), 1 = steady window, 2 = drain window,
    // 3 = teardown (discarded).
    let phase = AtomicU8::new(0);
    let lat: Mutex<Vec<(u8, f64)>> = Mutex::new(Vec::new());
    let clients = 6usize;
    let mut snaps: Option<(StatsSnapshot, StatsSnapshot, StatsSnapshot)> =
        None;
    let mut migrated = 0u64;
    std::thread::scope(|s| {
        for _ in 0..clients {
            let c = service.client();
            let (stop, phase, lat) = (&stop, &phase, &lat);
            s.spawn(move || {
                let mut live: VecDeque<GlobalAddr> = VecDeque::new();
                while !stop.load(Ordering::Relaxed) {
                    let t0 = Instant::now();
                    if let Ok(a) = c.alloc(1000) {
                        let dt = t0.elapsed().as_secs_f64() * 1e6;
                        lat.lock()
                            .unwrap()
                            .push((phase.load(Ordering::Relaxed), dt));
                        live.push_back(a);
                    }
                    if live.len() > 30 {
                        let _ = c.free(live.pop_front().unwrap());
                    }
                }
                for a in live {
                    let _ = c.free(a);
                }
            });
        }
        // Controller (scope body): warm up, measure steady state, then
        // measure the drain window.
        let ms = |n: u64| Duration::from_millis(n);
        std::thread::sleep(if smoke() { ms(15) } else { ms(40) });
        phase.store(1, Ordering::Relaxed);
        let s0 = service.snapshot();
        std::thread::sleep(if smoke() { ms(20) } else { ms(50) });
        let s1 = service.snapshot();
        phase.store(2, Ordering::Relaxed);
        let rep = if paced {
            service
                .drain_device_paced(
                    1,
                    DrainPacing {
                        blocks_per_tick: 4,
                        tick_pause: ms(2),
                    },
                )
                .expect("paced drain")
        } else {
            service.drain_device(1).expect("stop-the-world drain")
        };
        let s2 = service.snapshot();
        phase.store(3, Ordering::Relaxed);
        service.wait_lanes_quiet(1, failover_quiesce_timeout());
        service.retire_device(1);
        stop.store(true, Ordering::Relaxed);
        migrated = rep.migrated.len() as u64;
        snaps = Some((s0, s1, s2));
    });
    let (s0, s1, s2) = snaps.expect("controller ran");
    let modeled_delta = |a: &StatsSnapshot, b: &StatsSnapshot| {
        let ops = b.ops.saturating_sub(a.ops);
        let makespan = a
            .devices
            .iter()
            .zip(&b.devices)
            .map(|(da, db)| db.device_us - da.device_us)
            .fold(0.0f64, f64::max);
        if makespan > 0.0 { ops as f64 / makespan * 1e6 } else { 0.0 }
    };
    let steady = modeled_delta(&s0, &s1);
    let during = modeled_delta(&s1, &s2);
    let drain_lat: Vec<f64> = lat
        .into_inner()
        .unwrap()
        .into_iter()
        .filter(|(ph, _)| *ph == 2)
        .map(|(_, us)| us)
        .collect();
    let p99 = percentile(drain_lat, 0.99);
    let mode = if paced { "paced" } else { "stop-the-world" };
    println!(
        "service_throughput selfheal drain ({mode}): {migrated} migrated, \
         {steady:.0} ops/s modeled steady, {during:.0} during drain \
         (p99 alloc {p99:.1}us in-window)",
    );
    drop(service);
    (steady, during, p99, migrated)
}

/// Self-heal watchdog row: the acceptance scenario through
/// `run_selfheal_trace` — a member stalls mid-churn, the health
/// monitor detects / paced-drains / retires it with no manual call,
/// and the member is readmitted and serves again. Returns
/// (recovery µs, readmitted allocs).
fn run_selfheal_watchdog(allocs: usize) -> (f64, u64) {
    let service = AllocService::start_named_group(
        &[("t2000", Variant::Page); 3],
        &HeapConfig { num_chunks: 512, ..HeapConfig::default() },
        BatchPolicy::default(),
        RoutePolicy::RoundRobin,
        Arc::new(Cuda::new()),
    );
    service.set_forwarding_grace(Duration::from_secs(120));
    let policy = HealthPolicy {
        stall_window: Duration::from_millis(10),
        probation: Duration::from_millis(10),
        tick: Duration::from_millis(2),
        quiesce: Duration::from_millis(100),
        pace: DrainPacing {
            blocks_per_tick: 8,
            tick_pause: Duration::from_micros(500),
        },
        ..HealthPolicy::default()
    };
    let trace = churn_trace(0xC0FFEE, 48, allocs, 4096);
    let after = (trace.len() * 6 / 4) as u64;
    let rep = run_selfheal_trace(&service, 6, &trace, 8, 1, after, policy)
        .expect("selfheal trace");
    assert!(
        rep.events
            .iter()
            .any(|e| matches!(e.kind, HealthEventKind::Retired { .. })),
        "watchdog must retire the stalled member with no manual call"
    );
    assert!(
        rep.readmitted_allocs > 0,
        "readmitted member must serve fresh allocations"
    );
    println!(
        "service_throughput selfheal watchdog: auto-recovery in \
         {:.0}us (detect+drain+retire), readmitted member served \
         {} allocs",
        rep.recovery_us, rep.readmitted_allocs,
    );
    drop(service);
    (rep.recovery_us, rep.readmitted_allocs)
}

/// Device-group scaling row: `clients` pipelined clients over a
/// `devices`-member group. Returns (wall ops/s, modeled ops/s).
fn run_group(devices: usize, clients: usize, allocs: usize) -> (f64, f64) {
    let service = start_group(devices);
    let trace = rolling_trace(64, allocs, 1000);
    let t0 = Instant::now();
    let reps =
        run_group_trace(&service, clients, &trace, 32).expect("group trace");
    let dt = t0.elapsed().as_secs_f64();
    let agg = ServiceTraceReport::merged(&reps);
    assert_eq!(agg.alloc_failures, 0, "group workload must not OOM");
    let wall_ops = agg.submitted as f64 / dt;
    let snap = service.snapshot();
    let modeled_ops = snap.modeled_ops_per_sec();
    let per_device: Vec<String> = snap
        .devices
        .iter()
        .map(|d| format!("{}:{} ops/{:.0}us", d.name, d.ops, d.device_us))
        .collect();
    println!(
        "service_throughput group devices={devices} clients={clients}: \
         {wall_ops:.0} ops/s wall, {modeled_ops:.0} ops/s modeled \
         (makespan {:.0}us; {})",
        snap.modeled_makespan_us(),
        per_device.join(" "),
    );
    drop(service);
    (wall_ops, modeled_ops)
}

/// `OURO_SAN` overhead smoke: the same blocking single-client churn
/// with the shadow heap armed vs dormant. Informational — no gate; the
/// row exists so the sanitizer's cost stays visible in the perf record
/// (it is a debugging tool, not a production mode).
fn run_sanitizer_row(allocs: usize) -> (f64, f64) {
    fn churn(allocs: usize, san: bool) -> f64 {
        // Env is only read at service construction; main is
        // single-threaded here, so the set/remove pair cannot race.
        if san {
            std::env::set_var("OURO_SAN", "1");
        } else {
            std::env::remove_var("OURO_SAN");
        }
        let service = start_service(BatchPolicy::default());
        std::env::remove_var("OURO_SAN");
        assert_eq!(service.sanitizer().is_some(), san, "OURO_SAN gate");
        let client = service.client();
        let trace = rolling_trace(64, allocs, 1000);
        let mut addr = vec![None::<GlobalAddr>; 64];
        let t0 = Instant::now();
        let mut ops = 0u64;
        for op in &trace {
            match *op {
                TraceOp::Alloc { slot, size } => {
                    addr[slot] = Some(client.alloc(size).expect("alloc"));
                }
                TraceOp::Free { slot } => {
                    client.free(addr[slot].take().unwrap()).expect("free");
                }
            }
            ops += 1;
        }
        // Unwind the rolling window so the shadow heap's shutdown leak
        // check sees a balanced ledger.
        for a in addr.iter_mut().filter_map(Option::take) {
            client.free(a).expect("drain free");
            ops += 1;
        }
        let dt = t0.elapsed().as_secs_f64();
        if let Some(shadow) = service.sanitizer() {
            assert_eq!(shadow.live_count(), 0, "bench churn must balance");
        }
        drop(client);
        drop(service);
        ops as f64 / dt
    }
    let off = churn(allocs, false);
    let on = churn(allocs, true);
    println!(
        "service_throughput sanitizer: {on:.0} ops/s under OURO_SAN=1 \
         vs {off:.0} off ({:.2}x cost)",
        off / on.max(1e-9)
    );
    (off, on)
}

/// `OURO_LIN` overhead smoke, mirroring the sanitizer row: the same
/// blocking single-client churn with the history recorder armed vs
/// dormant, and (when armed) the harvested history fed through the
/// linearizability checker — so the row prices recording *and*
/// checking. Informational — no gate; like the shadow heap this is an
/// analysis mode, not a production one.
fn run_lincheck_row(allocs: usize) -> (f64, f64) {
    fn churn(allocs: usize, lin: bool) -> f64 {
        if lin {
            std::env::set_var("OURO_LIN", "1");
        } else {
            std::env::remove_var("OURO_LIN");
        }
        let service = start_service(BatchPolicy::default());
        std::env::remove_var("OURO_LIN");
        assert_eq!(service.history().is_some(), lin, "OURO_LIN gate");
        let client = service.client();
        let trace = rolling_trace(64, allocs, 1000);
        let mut addr = vec![None::<GlobalAddr>; 64];
        let t0 = Instant::now();
        let mut ops = 0u64;
        for op in &trace {
            match *op {
                TraceOp::Alloc { slot, size } => {
                    addr[slot] = Some(client.alloc(size).expect("alloc"));
                }
                TraceOp::Free { slot } => {
                    client.free(addr[slot].take().unwrap()).expect("free");
                }
            }
            ops += 1;
        }
        for a in addr.iter_mut().filter_map(Option::take) {
            client.free(a).expect("drain free");
            ops += 1;
        }
        if let Some(recorder) = service.history() {
            let history = recorder.harvest();
            assert!(history.len() as u64 >= ops, "recorder missed ops");
            ouroboros_tpu::check::linearize::check(&history)
                .unwrap_or_else(|v| panic!("bench churn must linearize:\n{v}"));
        }
        let dt = t0.elapsed().as_secs_f64();
        drop(client);
        drop(service);
        ops as f64 / dt
    }
    let off = churn(allocs, false);
    let on = churn(allocs, true);
    println!(
        "service_throughput lincheck: {on:.0} ops/s under OURO_LIN=1 \
         (record + check) vs {off:.0} off ({:.2}x cost)",
        off / on.max(1e-9)
    );
    (off, on)
}

fn main() {
    let allocs = if smoke() { 500 } else { 5_000 };

    // ---- async pipeline vs blocking (single client) ----------------------
    let (blocking, blocking_batch) = run_single_client(allocs, 0, "blocking   ");
    let (depth1, _) = run_single_client(allocs, 1, "async d=1  ");
    let (depth32, depth32_batch) = run_single_client(allocs, 32, "async d=32 ");
    let speedup = depth32 / blocking.max(1e-9);
    println!(
        "  -> async depth=32 vs blocking: {speedup:.2}x \
         (mean batch {depth32_batch:.2} vs {blocking_batch:.2})\n"
    );

    // ---- client-side lease cache vs the ring path (this PR's row) --------
    let (cached_ops, cached_ring_ops, cached_snap) = run_cached_pair(allocs);
    let cached_vs_ring = cached_ops / cached_ring_ops.max(1e-9);
    println!(
        "  -> lease cache vs same-service depth-32 ring: \
         {cached_vs_ring:.2}x\n"
    );
    let mixed_ops_per_client = if smoke() { 200 } else { 2_000 };
    let (mixed_cached_ops, mixed_delayed) =
        run_cached_mixed(mixed_ops_per_client);
    println!();

    // ---- device-group scaling (8 pipelined clients, this PR's row) -------
    let group_clients = 8usize;
    let group_allocs = if smoke() { 150 } else { 1_000 };
    let (wall1, modeled1) = run_group(1, group_clients, group_allocs);
    let (wall2, modeled2) = run_group(2, group_clients, group_allocs);
    let (wall4, modeled4) = run_group(4, group_clients, group_allocs);
    let group_speedup_modeled = modeled4 / modeled1.max(1e-9);
    let group_speedup_wall = wall4 / wall1.max(1e-9);
    println!(
        "  -> 4-device group vs single device: {group_speedup_modeled:.2}x \
         modeled, {group_speedup_wall:.2}x wall\n"
    );

    // ---- capacity-aware vs round-robin on a skewed group (this PR) -------
    let cap_quota = if smoke() { 2_600 } else { 7_600 };
    let (cap_rr, cap_rr_ok, cap_rr_failures) =
        run_capacity(RoutePolicy::RoundRobin, cap_quota);
    let (cap_ca, cap_ca_ok, cap_ca_failures) =
        run_capacity(RoutePolicy::CapacityAware, cap_quota);
    let cap_speedup = cap_ca / cap_rr.max(1e-9);
    println!(
        "  -> capacity-aware vs round-robin before first OOM: \
         {cap_speedup:.2}x modeled ({cap_ca_ok} vs {cap_rr_ok} allocs)\n"
    );

    // ---- failover: drain + retire a member mid-trace (this PR) -----------
    let failover_allocs = if smoke() { 300 } else { 1_500 };
    let (
        failover_modeled,
        failover_migrated,
        failover_forwarded,
        failover_skipped,
        failover_retired,
    ) = run_failover(failover_allocs);
    println!();

    // ---- self-heal: paced vs stop-the-world drain + watchdog (this PR) ---
    let (sh_stw_steady, sh_stw_during, sh_stw_p99, _sh_stw_migrated) =
        run_selfheal_pacing(false);
    let (sh_paced_steady, sh_paced_during, sh_paced_p99, sh_paced_migrated) =
        run_selfheal_pacing(true);
    let sh_paced_ratio = sh_paced_during / sh_paced_steady.max(1e-9);
    let sh_stw_ratio = sh_stw_during / sh_stw_steady.max(1e-9);
    println!(
        "  -> paced drain holds {sh_paced_ratio:.2}x of steady-state \
         modeled ops/s mid-drain (stop-the-world baseline: \
         {sh_stw_ratio:.2}x; p99 alloc {sh_paced_p99:.1}us vs \
         {sh_stw_p99:.1}us)\n"
    );
    let selfheal_allocs = if smoke() { 200 } else { 600 };
    let (sh_recovery_us, sh_readmitted) = run_selfheal_watchdog(selfheal_allocs);
    println!();

    // ---- federation: spillover routing + durable restart (this PR) -------
    let fed_clients = 6usize;
    let fed_ops = if smoke() { 300 } else { 2_000 };
    let (fed_base_wall, fed_base_modeled, _, _) =
        run_federation_churn(false, fed_clients, fed_ops);
    let (fed_spill_wall, fed_spill_modeled, fed_spilled, fed_xfrees) =
        run_federation_churn(true, fed_clients, fed_ops);
    let fed_ratio = fed_spill_modeled / fed_base_modeled.max(1e-9);
    println!(
        "  -> spillover federation holds {fed_ratio:.2}x of the \
         single-group modeled ops/s ({fed_spilled} spilled allocs, \
         {fed_xfrees} cross-group frees)\n"
    );
    let fed_restart_allocs = if smoke() { 300 } else { 1_500 };
    let (fed_restart_us, fed_lost, fed_leftover) =
        run_federation_restart(fed_restart_allocs);
    println!();

    // ---- shadow-heap sanitizer overhead (informational, ungated) ---------
    let san_allocs = if smoke() { 300 } else { 2_000 };
    let (san_off, san_on) = run_sanitizer_row(san_allocs);
    let san_overhead = san_off / san_on.max(1e-9);
    let (lin_off, lin_on) = run_lincheck_row(san_allocs);
    let lin_overhead = lin_off / lin_on.max(1e-9);
    println!();

    // ---- ring wakeup suppression vs eager notify (this PR's row) ---------
    let wake_clients = 8usize;
    let wake_allocs = if smoke() { 300 } else { 2_000 };
    let (wk_eager_wall, wk_eager_modeled, wk_eager_per_op, wk_eager_p99, wk_eager_snap) =
        run_wakeup_churn(true, wake_clients, wake_allocs);
    let (wk_sup_wall, wk_sup_modeled, wk_sup_per_op, wk_sup_p99, wk_sup_snap) =
        run_wakeup_churn(false, wake_clients, wake_allocs);
    let wakeup_reduction = wk_eager_per_op / wk_sup_per_op.max(1e-9);
    println!(
        "  -> EVENT_IDX suppression: {wakeup_reduction:.1}x fewer \
         wakeups/op than eager ({wk_sup_per_op:.3} vs \
         {wk_eager_per_op:.3}; ring p99 {wk_sup_p99:.1}us vs \
         {wk_eager_p99:.1}us loaded)\n"
    );

    let wk_broadcasts = wk_sup_snap.wakeup_delivered;
    let wk_broadcasts_sup = wk_sup_snap.wakeup_suppressed;
    let wk_doorbells = wk_sup_snap.doorbell_delivered;
    let wk_doorbells_sup = wk_sup_snap.doorbell_suppressed;
    let cached_mints = cached_snap.lease_mints;
    let cached_returns = cached_snap.lease_returns;
    let cached_p50 = cached_snap.cached_latency.p50_us;
    let cached_p99 = cached_snap.cached_latency.p99_us;
    let cached_p999 = cached_snap.cached_latency.p999_us;
    let ring_p50 = cached_snap.ring_latency.p50_us;
    let ring_p99 = cached_snap.ring_latency.p99_us;
    let ring_p999 = cached_snap.ring_latency.p999_us;
    let json = format!(
        "{{\n  \"bench\": \"service_throughput\",\n  \
         \"workload\": \"single client, rolling 1000 B trace, {allocs} allocs\",\n  \
         \"blocking_ops_per_sec\": {blocking:.1},\n  \
         \"blocking_mean_batch\": {blocking_batch:.3},\n  \
         \"async_depth1_ops_per_sec\": {depth1:.1},\n  \
         \"async_depth32_ops_per_sec\": {depth32:.1},\n  \
         \"async_depth32_mean_batch\": {depth32_batch:.3},\n  \
         \"speedup_depth32_vs_blocking\": {speedup:.3},\n  \
         \"cached_workload\": \"lease cache vs depth-32 ring, one \
         service, rolling 1000 B trace, {allocs} allocs; mixed row: 8 \
         clients (4 cached) over a shared pool, {mixed_ops_per_client} \
         allocs each\",\n  \
         \"cached_ops_per_sec\": {cached_ops:.1},\n  \
         \"cached_ring_depth32_ops_per_sec\": {cached_ring_ops:.1},\n  \
         \"cached_vs_depth32\": {cached_vs_ring:.3},\n  \
         \"cached_lease_mints\": {cached_mints},\n  \
         \"cached_lease_returns\": {cached_returns},\n  \
         \"cached_p50_us\": {cached_p50:.3},\n  \
         \"cached_p99_us\": {cached_p99:.3},\n  \
         \"cached_p999_us\": {cached_p999:.3},\n  \
         \"ring_p50_us\": {ring_p50:.3},\n  \
         \"ring_p99_us\": {ring_p99:.3},\n  \
         \"ring_p999_us\": {ring_p999:.3},\n  \
         \"mixed8_cached_ops_per_sec\": {mixed_cached_ops:.1},\n  \
         \"mixed8_delayed_frees\": {mixed_delayed},\n  \
         \"group_workload\": \"{group_clients} clients, depth-32 rolling \
         1000 B trace, {group_allocs} allocs each, round-robin\",\n  \
         \"group_devices1_ops_per_sec\": {wall1:.1},\n  \
         \"group_devices2_ops_per_sec\": {wall2:.1},\n  \
         \"group_devices4_ops_per_sec\": {wall4:.1},\n  \
         \"group_devices1_modeled_ops_per_sec\": {modeled1:.1},\n  \
         \"group_devices2_modeled_ops_per_sec\": {modeled2:.1},\n  \
         \"group_devices4_modeled_ops_per_sec\": {modeled4:.1},\n  \
         \"group_speedup_4v1_modeled\": {group_speedup_modeled:.3},\n  \
         \"group_speedup_4v1_wall\": {group_speedup_wall:.3},\n  \
         \"capacity_workload\": \"skewed 3-member group (64-chunk lp-sycl + \
         2x512-chunk cuda), 4 clients, alloc-only 1000 B to first OOM, \
         quota {cap_quota}\",\n  \
         \"capacity_roundrobin_modeled_ops_per_sec\": {cap_rr:.1},\n  \
         \"capacity_aware_modeled_ops_per_sec\": {cap_ca:.1},\n  \
         \"capacity_speedup_vs_roundrobin\": {cap_speedup:.3},\n  \
         \"capacity_roundrobin_ops_before_oom\": {cap_rr_ok},\n  \
         \"capacity_aware_ops_before_oom\": {cap_ca_ok},\n  \
         \"capacity_roundrobin_alloc_failures\": {cap_rr_failures},\n  \
         \"capacity_aware_alloc_failures\": {cap_ca_failures},\n  \
         \"failover_workload\": \"8 clients depth-32 rolling 1000 B, \
         {failover_allocs} allocs each, drain+retire member 1 at 25%\",\n  \
         \"failover_migrated\": {failover_migrated},\n  \
         \"failover_forwarded_frees\": {failover_forwarded},\n  \
         \"failover_skipped_frees\": {failover_skipped},\n  \
         \"failover_retired_inflight\": {failover_retired},\n  \
         \"failover_modeled_ops_per_sec\": {failover_modeled:.1},\n  \
         \"selfheal_workload\": \"6 churn clients, drain member 1 \
         mid-churn: paced (4 blocks / 2 ms tick) vs stop-the-world; \
         watchdog row stalls member 1 and self-heals (stall 10 ms, \
         probation 10 ms)\",\n  \
         \"selfheal_steady_modeled_ops_per_sec\": {sh_paced_steady:.1},\n  \
         \"selfheal_paced_during_modeled_ops_per_sec\": {sh_paced_during:.1},\n  \
         \"selfheal_stw_during_modeled_ops_per_sec\": {sh_stw_during:.1},\n  \
         \"selfheal_paced_vs_steady\": {sh_paced_ratio:.3},\n  \
         \"selfheal_stw_vs_steady\": {sh_stw_ratio:.3},\n  \
         \"selfheal_paced_p99_alloc_us\": {sh_paced_p99:.1},\n  \
         \"selfheal_stw_p99_alloc_us\": {sh_stw_p99:.1},\n  \
         \"selfheal_paced_migrated\": {sh_paced_migrated},\n  \
         \"selfheal_recovery_us\": {sh_recovery_us:.1},\n  \
         \"selfheal_readmitted_allocs\": {sh_readmitted},\n  \
         \"federation_workload\": \"{fed_clients} churn clients over a \
         2-group federation (2 members each, quorum 2), {fed_ops} allocs \
         each: primary latched by quorum loss vs a single-group \
         baseline; restart row kills+restores group 0 mid-trace\",\n  \
         \"federation_baseline_ops_per_sec\": {fed_base_wall:.1},\n  \
         \"federation_spillover_ops_per_sec\": {fed_spill_wall:.1},\n  \
         \"federation_baseline_modeled_ops_per_sec\": {fed_base_modeled:.1},\n  \
         \"federation_spillover_modeled_ops_per_sec\": {fed_spill_modeled:.1},\n  \
         \"federation_spillover_vs_baseline_modeled\": {fed_ratio:.3},\n  \
         \"federation_spilled_allocs\": {fed_spilled},\n  \
         \"federation_cross_group_frees\": {fed_xfrees},\n  \
         \"federation_restart_recovery_us\": {fed_restart_us},\n  \
         \"federation_restart_lost_blocks\": {fed_lost},\n  \
         \"federation_restart_leftover_swept\": {fed_leftover},\n  \
         \"sanitizer_workload\": \"single blocking client, rolling \
         1000 B trace, {san_allocs} allocs, OURO_SAN on vs off\",\n  \
         \"sanitizer_off_ops_per_sec\": {san_off:.1},\n  \
         \"sanitizer_on_ops_per_sec\": {san_on:.1},\n  \
         \"sanitizer_overhead_x\": {san_overhead:.3},\n  \
         \"lincheck_workload\": \"single blocking client, rolling \
         1000 B trace, {san_allocs} allocs, OURO_LIN record + check vs \
         off\",\n  \
         \"lincheck_off_ops_per_sec\": {lin_off:.1},\n  \
         \"lincheck_on_ops_per_sec\": {lin_on:.1},\n  \
         \"lincheck_overhead_x\": {lin_overhead:.3},\n  \
         \"wakeup_workload\": \"{wake_clients} clients, depth-32 rolling \
         1000 B trace, {wake_allocs} allocs each, one contended lane: \
         EVENT_IDX suppression vs eager notify\",\n  \
         \"wakeup_eager_ops_per_sec\": {wk_eager_wall:.1},\n  \
         \"wakeup_suppressed_ops_per_sec\": {wk_sup_wall:.1},\n  \
         \"wakeup_eager_modeled_ops_per_sec\": {wk_eager_modeled:.1},\n  \
         \"wakeup_suppressed_modeled_ops_per_sec\": {wk_sup_modeled:.1},\n  \
         \"wakeups_per_op_eager\": {wk_eager_per_op:.4},\n  \
         \"wakeups_per_op_suppressed\": {wk_sup_per_op:.4},\n  \
         \"wakeup_reduction_x\": {wakeup_reduction:.3},\n  \
         \"wakeup_broadcasts_delivered\": {wk_broadcasts},\n  \
         \"wakeup_broadcasts_suppressed\": {wk_broadcasts_sup},\n  \
         \"wakeup_doorbells_delivered\": {wk_doorbells},\n  \
         \"wakeup_doorbells_suppressed\": {wk_doorbells_sup},\n  \
         \"ring_p99_us_loaded_eager\": {wk_eager_p99:.3},\n  \
         \"ring_p99_us_loaded_suppressed\": {wk_sup_p99:.3}\n}}\n"
    );
    match std::fs::write("BENCH_service_throughput.json", &json) {
        Ok(()) => println!("wrote BENCH_service_throughput.json:\n{json}"),
        Err(e) => eprintln!("could not write perf record: {e}"),
    }

    // Acceptance gates (ISSUE 2): the pipeline must actually pay off.
    assert!(
        speedup >= 2.0,
        "async depth=32 must sustain >= 2x blocking ({depth32:.0} vs \
         {blocking:.0} ops/s)"
    );
    assert!(
        depth32_batch > blocking_batch,
        "async mean batch ({depth32_batch:.2}) must exceed blocking \
         ({blocking_batch:.2})"
    );

    // Acceptance gates (ISSUE 8): serving from the lease must actually
    // beat the pipelined ring path, on the same service and trace.
    assert!(
        cached_vs_ring >= 5.0,
        "lease cache must sustain >= 5x the depth-32 ring path \
         ({cached_ops:.0} vs {cached_ring_ops:.0} ops/s, \
         {cached_vs_ring:.2}x)"
    );
    assert!(
        cached_mints > 0 && cached_snap.cached_allocs > 0,
        "the cached leg must actually lease ({cached_mints} mints, {} \
         cached allocs)",
        cached_snap.cached_allocs
    );
    assert!(
        mixed_delayed > 0,
        "the mixed row must exercise the cross-client delayed-free \
         hand-off"
    );

    // Acceptance gate (ISSUE 3): the 4-device topology must scale.
    assert!(
        group_speedup_modeled >= 1.5,
        "4-device group must sustain >= 1.5x single-device modeled ops/s \
         ({modeled4:.0} vs {modeled1:.0})"
    );

    // Acceptance gate (ISSUE 4): occupancy-aware placement must beat
    // occupancy-blind round-robin on the skewed group before first OOM.
    assert!(
        cap_speedup >= 1.2,
        "capacity-aware must sustain >= 1.2x round-robin modeled ops/s \
         before first OOM ({cap_ca:.0} vs {cap_rr:.0})"
    );
    assert_eq!(
        cap_ca_failures, 0,
        "capacity-aware placement must shed the small member before OOM"
    );
    assert!(
        cap_rr_failures > 0,
        "the skewed workload must actually drive round-robin into OOM \
         (otherwise the sweep is not testing anything)"
    );

    // Acceptance gate (ISSUE 5): incremental background rebalancing
    // must keep serving — paced draining holds modeled client
    // throughput at >= 0.7x steady state while the live set moves
    // (the stop-the-world number is reported alongside, ungated).
    assert!(
        sh_paced_ratio >= 0.7,
        "paced drain must keep modeled ops/s >= 0.7x steady-state \
         during the sweep ({sh_paced_during:.0} vs {sh_paced_steady:.0} \
         ops/s, ratio {sh_paced_ratio:.2}; stop-the-world baseline \
         {sh_stw_ratio:.2})"
    );
    assert!(
        sh_paced_migrated > 0,
        "the pacing row must actually migrate a live set"
    );

    // Acceptance gates (ISSUE 7): spilled placement must not crater —
    // the standby group serves at the same modeled rate the baseline
    // group does (routing cost is host-side) — and the spill path must
    // actually have been exercised.
    assert!(
        fed_ratio >= 0.7,
        "spillover federation must hold >= 0.7x single-group modeled \
         ops/s ({fed_spill_modeled:.0} vs {fed_base_modeled:.0})"
    );
    assert!(
        fed_spilled > 0,
        "the spillover row must actually place cross-group"
    );
    assert!(
        fed_xfrees > 0,
        "the spillover row must actually free cross-group"
    );

    // Acceptance gates (ISSUE 9): the EVENT_IDX discipline must
    // actually coalesce the wakeup storm — and cost nothing.
    assert!(
        wakeup_reduction >= 4.0,
        "suppression must cut wakeups/op >= 4x vs eager \
         ({wk_sup_per_op:.3} vs {wk_eager_per_op:.3}, \
         {wakeup_reduction:.2}x)"
    );
    assert!(
        wk_sup_modeled >= 0.9 * wk_eager_modeled,
        "suppression must not regress modeled throughput \
         ({wk_sup_modeled:.0} vs {wk_eager_modeled:.0} ops/s)"
    );
    assert!(
        wk_broadcasts_sup > 0 && wk_doorbells_sup > 0,
        "the suppressed leg must actually elide notifies \
         ({wk_broadcasts_sup} broadcasts, {wk_doorbells_sup} doorbells)"
    );
    assert_eq!(
        wk_eager_snap.wakeup_suppressed + wk_eager_snap.doorbell_suppressed,
        0,
        "the eager baseline must never suppress"
    );
    for (leg, p99) in [("eager", wk_eager_p99), ("suppressed", wk_sup_p99)] {
        assert!(
            p99 > 0.0 && p99 < 250_000.0,
            "loaded ring p99 ({leg}) out of range: {p99:.1}us \
             (suppression must not turn reaps into timeouts)"
        );
    }

    // ---- sharded vs single-lane (multi-client, PR 1 row) -----------------
    for clients in [1usize, 2, 4, 8] {
        let single =
            run_multi_client(clients, BatchPolicy::single_lane(), "single-lane");
        let sharded =
            run_multi_client(clients, BatchPolicy::default(), "sharded   ");
        println!(
            "  -> sharded/single speedup at {clients} clients: {:.2}x\n",
            sharded / single.max(1e-9)
        );
    }
}
