//! Shared driver for the per-figure bench targets.
//!
//! Default runs use the quick axes so `cargo bench` completes in
//! minutes; set `OURO_BENCH_FULL=1` to sweep the paper's full axes
//! (all 11 sizes, thread counts to 10k, 10 iterations).

use ouroboros_tpu::harness::{figures, report};

pub fn run(fig: u32) {
    let full = std::env::var("OURO_BENCH_FULL").is_ok();
    let opts = figures::SweepOpts {
        quick: !full,
        iterations: if full { 10 } else { 4 },
        heap: Default::default(),
    };
    eprintln!(
        "figure {fig}: {} sweep ({} iterations/point)",
        if full { "full paper" } else { "quick (OURO_BENCH_FULL=1 for full)" },
        opts.iterations
    );
    let t0 = std::time::Instant::now();
    let r = figures::run_figure(fig, &opts).expect("figure sweep failed");
    print!("{}", report::render_figure(&r));
    report::write_figure(&r, std::path::Path::new("results")).expect("write results");
    println!(
        "figure {fig} regenerated in {:.1}s -> results/fig{fig}.{{txt,csv}}",
        t0.elapsed().as_secs_f64()
    );
}
