//! Ablation: warp-coalesced vs per-lane allocation (DESIGN.md ablation
//! index). The paper's own deoptimisation experiment found coalescing
//! bought nothing on the driver workload; this bench quantifies both the
//! modeled device cost and the hot-RMW traffic on this substrate, at a
//! converged warp (best case for coalescing) and across thread scales.
//!
//! Run: `cargo bench --bench ablation_coalescing`

use std::sync::Arc;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::ouroboros::{
    allocator::{warp_free, warp_malloc, warp_malloc_coalesced},
    build_allocator, HeapConfig, Variant,
};
use ouroboros_tpu::simt::{Device, DeviceProfile, Grid};

fn main() {
    for threads in [32u32, 1024, 4096] {
        for (name, coalesced) in [("per-lane", false), ("coalesced", true)] {
            let device =
                Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
            let alloc = build_allocator(Variant::Page, &HeapConfig::default());
            let alloc2 = alloc.clone();
            // Warm iteration to populate queues (steady-state, like the
            // paper's subsequent-iterations metric).
            for _ in 0..2 {
                let a3 = alloc2.clone();
                device.launch("warm", Grid::new(threads), move |w| {
                    let lanes: Vec<u32> = w.active_lanes().collect();
                    let sizes = vec![1000u32; lanes.len()];
                    let rs = warp_malloc(a3.as_ref(), w, &sizes);
                    let addrs: Vec<Option<u32>> =
                        rs.iter().map(|r| r.as_ref().ok().copied()).collect();
                    warp_free(a3.as_ref(), w, &addrs);
                });
            }
            let a3 = alloc2.clone();
            let st = device.launch("measured", Grid::new(threads), move |w| {
                let lanes: Vec<u32> = w.active_lanes().collect();
                let sizes = vec![1000u32; lanes.len()];
                let rs = if coalesced {
                    warp_malloc_coalesced(a3.as_ref(), w, &sizes)
                } else {
                    warp_malloc(a3.as_ref(), w, &sizes)
                };
                let addrs: Vec<Option<u32>> =
                    rs.iter().map(|r| r.as_ref().ok().copied()).collect();
                warp_free(a3.as_ref(), w, &addrs);
            });
            println!(
                "ablation coalescing threads={threads} {name}: \
                 {:.2} us device, {} atomics, {} hot-serial cycles",
                st.device_us, st.events.atomics, st.events.hot_serial_cycles
            );
        }
    }
    println!(
        "\ninterpretation: coalescing trades per-lane RMW traffic for a \
         serial leader section — a wash at low thread counts (the paper's \
         deopt result), a hot-word win only at high contention."
    );
}
