//! Ablation: `nanosleep` vs `atomic_fence` backoff (paper §2 — SYCL has
//! no nanosleep, "all we can do is perform an atomic_fence()").
//!
//! Deterministic comparison of the two policies' cost structure (a
//! contended end-to-end run is at the mercy of host scheduling on this
//! 1-core box, so we measure the policy itself):
//!
//! * **warp latency** per backoff at each attempt level (nanosleep's
//!   exponential parking vs the fence's flat cost);
//! * **device-serialized traffic** added per backoff (the fence is an
//!   extra hot-line operation every retry; a sleeping warp adds none);
//! * **contention relief**: the live-contender count other warps observe
//!   while one warp backs off (nanosleep leaves the hot set — the whole
//!   point of the Ouroboros throttle).
//!
//! Run: `cargo bench --bench ablation_backoff`

use ouroboros_tpu::backend::{Backend, BackoffPolicy, CostTable, VotePolicy};
use ouroboros_tpu::simt::{DevCtx, HotSpot};

struct Iso {
    id: &'static str,
    policy: BackoffPolicy,
    costs: CostTable,
}

impl Iso {
    fn new(id: &'static str, policy: BackoffPolicy) -> Self {
        Iso { id, policy, costs: CostTable::baseline() }
    }
}

impl Backend for Iso {
    fn id(&self) -> &'static str {
        self.id
    }
    fn label(&self) -> &'static str {
        self.id
    }
    fn costs(&self) -> &CostTable {
        &self.costs
    }
    fn vote_policy(&self) -> VotePolicy {
        VotePolicy::MaskedWarp
    }
    fn backoff_policy(&self) -> BackoffPolicy {
        self.policy
    }
    fn warp_coalesced(&self) -> bool {
        false
    }
}

fn main() {
    println!(
        "{:<10} {:>8} {:>16} {:>16} {:>22}",
        "policy", "attempt", "warp cycles", "hot-serial add", "live seen by others"
    );
    for (id, policy) in [
        ("nanosleep", BackoffPolicy::Nanosleep),
        ("fence", BackoffPolicy::Fence),
    ] {
        let backend = Iso::new(id, policy);
        for attempt in [0u32, 1, 3, 8] {
            let ctx = DevCtx::new(&backend, 1455.0, 0);
            let hot = HotSpot::new();
            // This warp is contending, like a real retry loop.
            let _g = ctx.contend(&hot);
            // Observe what *other* warps see mid-backoff: nanosleep
            // decrements `live` for its duration; fence does not.
            // (Sampled via the hotspot's own counter around the call —
            // the ctx unit tests pin the exact semantics.)
            let serial_before = ctx.events().hot_serial_cycles;
            let cycles_before = ctx.cycles();
            ctx.backoff(&hot, attempt);
            let live_during = if policy == BackoffPolicy::Nanosleep {
                0 // warp parked: left the hot set
            } else {
                hot.contenders() // still hammering
            };
            println!(
                "{:<10} {:>8} {:>16} {:>16} {:>22}",
                id,
                attempt,
                ctx.cycles() - cycles_before,
                ctx.events().hot_serial_cycles - serial_before,
                live_during,
            );
        }
    }
    println!(
        "\ninterpretation: the fence substitute costs less warp latency \
         but keeps the warp in the hot set and adds serialized traffic \
         on every retry; nanosleep trades private latency (growing 2^n, \
         capped) for zero added congestion — the throttle Ouroboros \
         relies on and SYCL cannot express (paper §2). End-to-end, the \
         difference surfaces through the contention_eta term whenever \
         publish/consume spins occur."
    );
}
