//! Microbenchmarks of the three queue flavors (host wall time — the L3
//! hot-path perf signal for EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench queue_ops`

use std::sync::Arc;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::ouroboros::{
    index_queue::IndexQueue, virtual_queue::{VaQueue, VlQueue}, Heap,
    HeapConfig, IdQueue,
};
use ouroboros_tpu::simt::DevCtx;
use ouroboros_tpu::util::bench;

const OPS: u32 = 10_000;

fn churn(q: &dyn IdQueue, ctx: &DevCtx) {
    for v in 0..OPS {
        q.try_enqueue(ctx, v).expect("enqueue");
        if v % 4 == 3 {
            for _ in 0..4 {
                q.try_dequeue(ctx).expect("dequeue");
            }
        }
    }
}

fn bulk_churn(q: &dyn IdQueue, ctx: &DevCtx) {
    let vals: Vec<u32> = (0..32).collect();
    let mut out = Vec::with_capacity(32);
    for _ in 0..OPS / 32 {
        q.bulk_enqueue(ctx, &vals).expect("bulk enqueue");
        out.clear();
        q.bulk_dequeue(ctx, 32, &mut out);
        assert_eq!(out.len(), 32);
    }
}

fn main() {
    let b = Cuda::new();
    let ctx = DevCtx::new(&b, 1455.0, 0);
    let heap = || Arc::new(Heap::new(HeapConfig::default()));

    let iq = IndexQueue::new(OPS + 64);
    bench::bench("index_queue/churn_10k", 1, 10, || churn(&iq, &ctx));
    bench::bench("index_queue/bulk_churn_10k", 1, 10, || bulk_churn(&iq, &ctx));

    let va = VaQueue::new(heap(), 64, 2046);
    bench::bench("va_queue/churn_10k", 1, 10, || churn(&va, &ctx));
    bench::bench("va_queue/bulk_churn_10k", 1, 10, || bulk_churn(&va, &ctx));

    let vl = VlQueue::new(heap(), OPS + 64, 2046);
    bench::bench("vl_queue/churn_10k", 1, 10, || churn(&vl, &ctx));
    bench::bench("vl_queue/bulk_churn_10k", 1, 10, || bulk_churn(&vl, &ctx));

    // Modeled device-cycle comparison (what the figures are made of).
    for (name, q) in [
        ("index", &iq as &dyn IdQueue),
        ("va", &va as &dyn IdQueue),
        ("vl", &vl as &dyn IdQueue),
    ] {
        let c2 = DevCtx::new(&b, 1455.0, 0);
        churn(q, &c2);
        println!(
            "cycles {name}_queue churn_10k: {} device cycles, {} hot-serial",
            c2.cycles(),
            c2.events().hot_serial_cycles
        );
    }
}
