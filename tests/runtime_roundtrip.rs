//! PJRT runtime integration: the AOT artifacts round-trip against the
//! independent host mirrors. Requires `make artifacts` (the Makefile
//! `test` target guarantees it).

use ouroboros_tpu::ouroboros::params;
use ouroboros_tpu::runtime::{pattern, Runtime};

fn runtime() -> Runtime {
    Runtime::load_default().expect(
        "artifacts not found or stale — run `make artifacts` before \
         `cargo test`",
    )
}

#[test]
fn manifest_agrees_with_rust_geometry() {
    let rt = runtime();
    let m = &rt.manifest;
    assert_eq!(m.smallest_page, params::SMALLEST_PAGE);
    assert_eq!(m.chunk_size, params::CHUNK_SIZE);
    assert_eq!(m.bitmap_words as usize, params::BITMAP_WORDS);
    assert_eq!(m.mix_a as i32, pattern::MIX_A);
    assert_eq!(m.mix_b as i32, pattern::MIX_B);
}

#[test]
fn workload_step_matches_host_pattern() {
    let rt = runtime();
    let m = rt.manifest.clone();
    let offsets: Vec<i32> =
        (0..m.touch_pages as i32).map(|i| i.wrapping_mul(8192)).collect();
    for seed in [0, 42, -7] {
        let out = rt.workload_step(&offsets, seed).unwrap();
        assert_eq!(out.checksums.len(), m.touch_pages as usize);
        assert_eq!(out.buf.len(), (m.touch_pages * m.page_words) as usize);
        for (i, &off) in offsets.iter().enumerate() {
            assert_eq!(
                out.checksums[i],
                pattern::expected_checksum(off, m.page_words, seed),
                "checksum mismatch page {i} seed {seed}"
            );
            assert_eq!(out.probe[i], pattern::expected_word(off, 0, seed));
            // Spot-check full words of the page image.
            let row = &out.buf
                [i * m.page_words as usize..(i + 1) * m.page_words as usize];
            for j in [0usize, 1, m.page_words as usize - 1] {
                assert_eq!(
                    row[j],
                    pattern::expected_word(off, j as i32, seed),
                    "word {j} of page {i}"
                );
            }
        }
    }
}

#[test]
fn plan_alloc_matches_host_binning_and_scan() {
    let rt = runtime();
    let m = rt.manifest.clone();
    let sizes: Vec<i32> = (0..m.plan_batch as i32)
        .map(|i| 1 + (i * 97) % params::CHUNK_SIZE as i32)
        .collect();
    // Craft bitmaps with known first-free positions.
    let words = m.bitmap_words as usize;
    let mut bitmaps = vec![0u32; m.plan_chunks as usize * words];
    for c in 0..m.plan_chunks as usize {
        let first_free = c % 513; // 512 == full
        for bit in 0..first_free.min(512) {
            bitmaps[c * words + bit / 32] |= 1 << (bit % 32);
        }
    }
    let plan = rt.plan_alloc(&sizes, &bitmaps).unwrap();
    for (i, &s) in sizes.iter().enumerate() {
        assert_eq!(
            plan.queue_idx[i],
            params::queue_for_size(s as u32).unwrap() as i32
        );
    }
    for c in 0..m.plan_chunks as usize {
        let expect = if c % 513 == 512 { -1 } else { (c % 513) as i32 };
        assert_eq!(plan.first_free[c], expect, "chunk {c}");
        assert_eq!(plan.free_count[c], 512 - (c % 513) as i32);
    }
}

#[test]
fn frag_report_matches_host_model() {
    let rt = runtime();
    let m = rt.manifest.clone();
    let words = m.bitmap_words as usize;
    let mut bitmaps = vec![0u32; m.plan_chunks as usize * words];
    for c in 0..m.plan_chunks as usize {
        match c % 4 {
            0 => {} // empty: run == free == 512, score 0
            1 => bitmaps[c * words..(c + 1) * words].fill(u32::MAX), // full
            2 => bitmaps[c * words..(c + 1) * words].fill(0x5555_5555),
            _ => {
                // Single free run of 8 pages at bit 60..67.
                bitmaps[c * words..(c + 1) * words].fill(u32::MAX);
                bitmaps[c * words + 1] &= !(0xFu32 << 28);
                bitmaps[c * words + 2] &= !0xFu32;
            }
        }
    }
    let out = rt.frag_report(&bitmaps).unwrap();
    for c in 0..m.plan_chunks as usize {
        match c % 4 {
            0 => {
                assert_eq!(out.free_count[c], 512);
                assert_eq!(out.longest_run[c], 512);
                assert_eq!(out.frag_score[c], 0);
            }
            1 => {
                assert_eq!(out.free_count[c], 0);
                assert_eq!(out.longest_run[c], 0);
                assert_eq!(out.frag_score[c], 0);
            }
            2 => {
                assert_eq!(out.free_count[c], 256);
                assert_eq!(out.longest_run[c], 1);
                assert_eq!(out.frag_score[c], 1000 - 1000 / 256);
            }
            _ => {
                assert_eq!(out.free_count[c], 8);
                assert_eq!(out.longest_run[c], 8, "chunk {c}");
                assert_eq!(out.frag_score[c], 0);
            }
        }
    }
}

#[test]
fn wrong_shapes_rejected() {
    let rt = runtime();
    assert!(rt.workload_step(&[0i32; 3], 1).is_err());
    assert!(rt.plan_alloc(&[0i32; 3], &[0u32; 4]).is_err());
    assert!(rt.frag_report(&[0u32; 7]).is_err());
}
