//! Property-based integration tests over the full allocator contract —
//! all six variants satisfy the same invariants (hand-rolled harness in
//! `util::prop`; seeds overridable via OURO_PROP_SEED / OURO_PROP_CASES).

use std::collections::HashMap;

use ouroboros_tpu::backend::{Backend, Cuda};
use ouroboros_tpu::coordinator::workload::{churn_trace, TraceOp};
use ouroboros_tpu::ouroboros::{
    build_allocator, params, AllocError, DeviceAllocator, HeapConfig, Variant,
};
use ouroboros_tpu::prop_assert;
use ouroboros_tpu::simt::DevCtx;
use ouroboros_tpu::util::prop;

fn small_cfg() -> HeapConfig {
    HeapConfig {
        num_chunks: 128,
        queue_capacity: 8192,
        va_dir_slots: 16,
        ..HeapConfig::default()
    }
}

/// Live allocations must occupy disjoint byte ranges sized >= request.
fn check_no_overlap(
    live: &HashMap<usize, (u32, u32)>,
) -> Result<(), String> {
    let mut ranges: Vec<(u32, u32)> = live
        .values()
        .map(|&(addr, size)| {
            let q = params::queue_for_size(size).unwrap();
            (addr, addr + params::page_size(q))
        })
        .collect();
    ranges.sort_unstable();
    for w in ranges.windows(2) {
        if w[0].1 > w[1].0 {
            return Err(format!(
                "overlapping live allocations: {:?} vs {:?}",
                w[0], w[1]
            ));
        }
    }
    Ok(())
}

fn churn_property(variant: Variant) {
    prop::check(&format!("churn-{}", variant.id()), |g| {
        let seed = g.rng().next_u64();
        let slots = g.sized_range(4, 48) as usize;
        let ops = g.sized_range(20, 400) as usize;
        let max_size = *g.pick(&[256u32, 1024, 8192]);
        let trace = churn_trace(seed, slots, ops, max_size);

        let alloc = build_allocator(variant, &small_cfg());
        let b = Cuda::new();
        let ctx = DevCtx::new(&b, 1000.0, 0);
        let mut live: HashMap<usize, (u32, u32)> = HashMap::new();

        for op in &trace {
            match *op {
                TraceOp::Alloc { slot, size } => {
                    let addr = alloc
                        .malloc(&ctx, size)
                        .map_err(|e| format!("malloc({size}) failed: {e}"))?;
                    prop_assert!(
                        addr % params::page_size(
                            params::queue_for_size(size).unwrap()
                        ) == 0,
                        "misaligned address {addr:#x} for size {size}"
                    );
                    live.insert(slot, (addr, size));
                    check_no_overlap(&live)?;
                }
                TraceOp::Free { slot } => {
                    let (addr, _) = live.remove(&slot).unwrap();
                    alloc
                        .free(&ctx, addr)
                        .map_err(|e| format!("free({addr:#x}) failed: {e}"))?;
                }
            }
        }
        // Trace is balanced: the allocator must be drained + consistent.
        prop_assert!(live.is_empty(), "trace not balanced");
        prop_assert!(
            alloc.debug_consistent(),
            "allocator inconsistent after balanced churn"
        );
        // And after a quiescent sweep, chunk-based variants return every
        // chunk to the heap.
        let reclaimed = alloc.sweep(&ctx);
        let _ = reclaimed;
        Ok(())
    });
}

#[test]
fn churn_page() {
    churn_property(Variant::Page);
}

#[test]
fn churn_chunk() {
    churn_property(Variant::Chunk);
}

#[test]
fn churn_va_page() {
    churn_property(Variant::VaPage);
}

#[test]
fn churn_vl_page() {
    churn_property(Variant::VlPage);
}

#[test]
fn churn_va_chunk() {
    churn_property(Variant::VaChunk);
}

#[test]
fn churn_vl_chunk() {
    churn_property(Variant::VlChunk);
}

/// Free -> alloc recycling: a bounded heap survives unbounded churn.
#[test]
fn bounded_heap_survives_unbounded_churn() {
    prop::check("recycling", |g| {
        let variant = *g.pick(&Variant::all());
        let alloc = build_allocator(variant, &small_cfg());
        let b = Cuda::new();
        let ctx = DevCtx::new(&b, 1000.0, 0);
        let size = g.sized_range(1, 8192) as u32;
        // Far more total allocations than the heap could hold at once.
        for round in 0..200 {
            let a = alloc.malloc(&ctx, size).map_err(|e| {
                format!("{}: round {round} malloc({size}): {e}", variant.id())
            })?;
            alloc.free(&ctx, a).map_err(|e| format!("free: {e}"))?;
        }
        Ok(())
    });
}

/// The allocator returns page-aligned addresses whose page fits the
/// request — and the same property holds for every variant on the same
/// trace (cross-variant equivalence of the allocation contract).
#[test]
fn cross_variant_contract_equivalence() {
    prop::check("cross-variant", |g| {
        let sizes: Vec<u32> = g.vec(1, 24, |g| g.sized_range(1, 8192) as u32);
        for variant in Variant::all() {
            let alloc = build_allocator(variant, &small_cfg());
            let b = Cuda::new();
            let ctx = DevCtx::new(&b, 1000.0, 0);
            let mut addrs = Vec::new();
            for &s in &sizes {
                let a = alloc
                    .malloc(&ctx, s)
                    .map_err(|e| format!("{}: {e}", variant.id()))?;
                let q = params::queue_for_size(s).unwrap();
                prop_assert!(
                    a % params::page_size(q) == 0,
                    "{}: misaligned {a:#x}",
                    variant.id()
                );
                addrs.push(a);
            }
            let mut u = addrs.clone();
            u.sort_unstable();
            u.dedup();
            prop_assert!(
                u.len() == addrs.len(),
                "{}: duplicate addresses",
                variant.id()
            );
            for a in addrs {
                alloc.free(&ctx, a).map_err(|e| format!("free: {e}"))?;
            }
        }
        Ok(())
    });
}

/// Concurrent malloc/free from real threads: unique addresses, full
/// drain, consistent bitmaps.
#[test]
fn concurrent_churn_all_variants() {
    for variant in Variant::all() {
        let alloc = build_allocator(variant, &small_cfg());
        let failed = std::sync::atomic::AtomicU32::new(0);
        std::thread::scope(|s| {
            for t in 0..4u32 {
                let alloc = alloc.clone();
                let failed = &failed;
                s.spawn(move || {
                    let b = Cuda::new();
                    let ctx = DevCtx::new(&b, 1000.0, t);
                    let mut mine = Vec::new();
                    for i in 0..200u32 {
                        let size = 16 + (t * 997 + i * 131) % 2000;
                        match alloc.malloc(&ctx, size) {
                            Ok(a) => mine.push(a),
                            Err(AllocError::OutOfMemory) => {
                                // Churn pressure: free half and go on.
                                for a in mine.drain(..mine.len() / 2) {
                                    alloc.free(&ctx, a).unwrap();
                                }
                            }
                            Err(e) => {
                                eprintln!("{}: {e}", variant.id());
                                failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                        if i % 3 == 2 {
                            if let Some(a) = mine.pop() {
                                alloc.free(&ctx, a).unwrap();
                            }
                        }
                    }
                    for a in mine {
                        alloc.free(&ctx, a).unwrap();
                    }
                });
            }
        });
        assert_eq!(
            failed.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "{}: unexpected errors",
            variant.id()
        );
        assert!(alloc.debug_consistent(), "{}", variant.id());
    }
}

/// Error taxonomy is stable across variants.
#[test]
fn error_taxonomy() {
    let b = Cuda::new();
    for variant in Variant::all() {
        let alloc = build_allocator(variant, &small_cfg());
        let ctx = DevCtx::new(&b, 1000.0, 0);
        assert_eq!(alloc.malloc(&ctx, 0), Err(AllocError::ZeroSize));
        assert_eq!(
            alloc.malloc(&ctx, params::CHUNK_SIZE + 1),
            Err(AllocError::TooLarge(params::CHUNK_SIZE + 1))
        );
        // Wild frees rejected.
        assert!(matches!(
            alloc.free(&ctx, 12345 * params::CHUNK_SIZE),
            Err(AllocError::InvalidFree(_))
        ));
        let a = alloc.malloc(&ctx, 100).unwrap();
        assert!(matches!(
            alloc.free(&ctx, a + 4),
            Err(AllocError::InvalidFree(_))
        ));
        alloc.free(&ctx, a).unwrap();
        assert!(matches!(
            alloc.free(&ctx, a),
            Err(AllocError::InvalidFree(_))
        ));
    }
}
