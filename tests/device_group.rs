//! Device-group topology integration: randomized cross-device frees
//! under every routing policy, heterogeneous group members, and ticket
//! provenance across service instances.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::router::RoutePolicy;
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::ouroboros::{
    build_allocator, AllocError, GlobalAddr, HeapConfig, Variant,
};
use ouroboros_tpu::simt::{Device, DeviceProfile};
use ouroboros_tpu::util::rng::Rng;

/// A heterogeneous 3-device group — two t2000s around an Iris Xe
/// (subgroup width 16), each member running a *different* allocator
/// variant over its own heap.
fn hetero_group(route: RoutePolicy) -> AllocService {
    AllocService::start_named_group(
        &[
            ("t2000", Variant::Page),
            ("iris-xe", Variant::Chunk),
            ("t2000", Variant::VlChunk),
        ],
        &HeapConfig { num_chunks: 512, ..HeapConfig::default() },
        BatchPolicy::default(),
        route,
        Arc::new(Cuda::new()),
    )
}

/// Randomized multi-client property test, run under **all three**
/// routing policies: 8 clients share one pool of live allocations, so
/// an address allocated by a client placed on device A is routinely
/// freed through a client whose affinity is device B. Invariants:
///
/// * the global live-set never holds a duplicate address (no
///   double-allocation across devices);
/// * every free lands on the owning device — per-device service
///   alloc/free counts balance exactly after the drain;
/// * each member heap's chunk accounting stays consistent
///   (`chunks_released` never exceeds what was ever carved or reused)
///   and its allocator passes `debug_consistent`.
#[test]
fn cross_device_frees_consistent_under_every_policy() {
    for route in RoutePolicy::all() {
        let svc = hetero_group(route);
        // (live addresses, duplicate-detection set) — one lock so the
        // two views never diverge.
        let pool: Mutex<(Vec<GlobalAddr>, HashSet<GlobalAddr>)> =
            Mutex::new((Vec::new(), HashSet::new()));
        let cross_frees = AtomicU64::new(0);
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let c = svc.client();
                let pool = &pool;
                let cross_frees = &cross_frees;
                s.spawn(move || {
                    let mut rng = Rng::new(0xD06E + t * 7919);
                    for _ in 0..120 {
                        if rng.chance(0.55) {
                            let size = rng.range(1, 8192) as u32;
                            let addr = c.alloc(size).unwrap_or_else(|e| {
                                panic!("{}: alloc({size}): {e}", route.id())
                            });
                            let mut g = pool.lock().unwrap();
                            assert!(
                                g.1.insert(addr),
                                "{}: duplicate live address {addr}",
                                route.id()
                            );
                            g.0.push(addr);
                        } else {
                            // Free a *random* live allocation — almost
                            // always minted by another client, often on
                            // another device.
                            let victim = {
                                let mut g = pool.lock().unwrap();
                                if g.0.is_empty() {
                                    continue;
                                }
                                let i = rng.below(g.0.len() as u64) as usize;
                                let a = g.0.swap_remove(i);
                                assert!(g.1.remove(&a));
                                a
                            };
                            if victim.device() as usize != c.affinity() {
                                cross_frees.fetch_add(1, Ordering::Relaxed);
                            }
                            c.free(victim).unwrap_or_else(|e| {
                                panic!("{}: free({victim}): {e}", route.id())
                            });
                        }
                    }
                });
            }
        });
        assert!(
            cross_frees.load(Ordering::Relaxed) > 0,
            "{}: the workload never exercised a cross-affinity free",
            route.id()
        );

        // Drain the surviving pool through a single client (more
        // cross-device frees: this handle has one affinity, the pool
        // spans all three devices).
        let drainer = svc.client();
        let (leftovers, set) = {
            let mut g = pool.lock().unwrap();
            (std::mem::take(&mut g.0), std::mem::take(&mut g.1))
        };
        assert_eq!(leftovers.len(), set.len());
        for a in leftovers {
            drainer.free(a).unwrap_or_else(|e| {
                panic!("{}: drain free({a}): {e}", route.id())
            });
        }

        let snap = svc.snapshot();
        assert_eq!(snap.allocs, snap.frees, "{}: {snap:?}", route.id());
        assert_eq!(snap.devices.len(), 3);
        for d in &snap.devices {
            assert_eq!(
                d.allocs, d.frees,
                "{}: frees did not balance on the owning device: {snap:?}",
                route.id()
            );
        }
        // Per-device rollups partition the aggregate.
        assert_eq!(
            snap.devices.iter().map(|d| d.ops).sum::<u64>(),
            snap.ops,
            "{}",
            route.id()
        );

        let allocators = svc.allocators();
        drop(svc);
        for (i, a) in allocators.iter().enumerate() {
            assert!(
                a.debug_consistent(),
                "{}: device {i} allocator inconsistent after drain",
                route.id()
            );
            assert_eq!(
                a.counters().mallocs.load(Ordering::Relaxed),
                a.counters().frees.load(Ordering::Relaxed),
                "{}: device {i} allocator counters unbalanced",
                route.id()
            );
            let hs = &a.heap().stats;
            let bumped = hs.chunks_bumped.load(Ordering::Relaxed);
            let reused = hs.chunks_reused.load(Ordering::Relaxed);
            let released = hs.chunks_released.load(Ordering::Relaxed);
            assert!(
                released <= bumped + reused,
                "{}: device {i} released {released} chunks but only \
                 carved {bumped} + reused {reused}",
                route.id()
            );
        }
    }
}

/// Every policy keeps working when allocations outlive the clients that
/// made them and devices are heterogeneous — the blocking smoke path.
#[test]
fn hetero_group_blocking_roundtrip() {
    let svc = hetero_group(RoutePolicy::RoundRobin);
    let c = svc.client();
    let addrs: Vec<GlobalAddr> =
        (0..9).map(|_| c.alloc(1000).unwrap()).collect();
    // Round-robin over 3 devices: 3 allocs each, tagged accordingly.
    for dev in 0..3u32 {
        assert_eq!(
            addrs.iter().filter(|a| a.device() == dev).count(),
            3,
            "{addrs:?}"
        );
    }
    // Unique global addresses even though local addresses collide
    // across the (independent) heaps.
    let uniq: HashSet<GlobalAddr> = addrs.iter().copied().collect();
    assert_eq!(uniq.len(), addrs.len());
    for a in addrs {
        c.free(a).unwrap();
    }
    // Double free on a specific device reports the tagged address.
    let b = c.alloc(100).unwrap();
    c.free(b).unwrap();
    match c.free(b) {
        Err(AllocError::InvalidFree(raw)) => assert_eq!(raw, b.raw()),
        other => panic!("double free returned {other:?}"),
    }
}

/// Targeted `LeastLoaded` tie-break coverage: a serial blocking client
/// reaps every op before the next submit, so the router probes all-zero
/// ring occupancy on every call — the rotating tie-break must spread
/// the allocations evenly instead of silently degrading the policy to
/// device 0 (previously only exercised incidentally by the churn test).
#[test]
fn least_loaded_ties_rotate_across_devices() {
    let svc = hetero_group(RoutePolicy::LeastLoaded);
    let c = svc.client();
    let addrs: Vec<GlobalAddr> =
        (0..12).map(|_| c.alloc(1000).unwrap()).collect();
    for dev in 0..3u32 {
        assert_eq!(
            addrs.iter().filter(|a| a.device() == dev).count(),
            4,
            "all-tied occupancy must rotate, not pile up: {addrs:?}"
        );
    }
    // No two consecutive serial allocations land on the same device
    // while everything is tied — that is what "rotates with the
    // cursor" means.
    for w in addrs.windows(2) {
        assert_ne!(w[0].device(), w[1].device(), "{addrs:?}");
    }
    for a in addrs {
        c.free(a).unwrap();
    }
}

/// Targeted `ClientAffinity` coverage: affinities are assigned
/// round-robin at handle creation and are *not* reclaimed when a
/// handle drops — a new handle continues the rotation, so a
/// create/drop/create cycle never strands every client on one device.
#[test]
fn client_affinity_rotation_survives_handle_drop() {
    let svc = hetero_group(RoutePolicy::ClientAffinity);
    let c0 = svc.client();
    let c1 = svc.client();
    assert_eq!((c0.affinity(), c1.affinity()), (0, 1));
    // The dropped handle's slot is not reused out of order: the next
    // handle continues the rotation (2), then wraps (0).
    drop(c0);
    let c2 = svc.client();
    let c3 = svc.client();
    assert_eq!((c2.affinity(), c3.affinity()), (2, 0));
    // Clones are fresh handles, not affinity copies: cloning c2
    // (affinity 2) yields the next rotation slot (1), not a copy of 2
    // — the discriminating case, since copying would break the
    // round-robin spread whenever handles multiply by cloning.
    let c4 = c2.clone();
    assert_eq!(c4.affinity(), 1);
    // Each handle's allocations pin to its affinity device.
    for (c, dev) in [(&c1, 1u32), (&c2, 2), (&c3, 0), (&c4, 1)] {
        let a = c.alloc(256).unwrap();
        assert_eq!(a.device(), dev, "affinity {} misrouted", c.affinity());
        c.free(a).unwrap();
    }
}

/// Ticket provenance across *instances*: a ticket minted by one service
/// — even one with a different (larger) lane table — is rejected
/// deterministically by another, and still served by its minter.
#[test]
fn foreign_tickets_rejected_across_group_services() {
    let svc_big = hetero_group(RoutePolicy::RoundRobin);
    let svc_small = {
        let device =
            Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
        let alloc = build_allocator(Variant::Page, &HeapConfig::test_small());
        AllocService::start(device, alloc, BatchPolicy::default())
    };
    let c_big = svc_big.client();
    let c_small = svc_small.client();
    // A ticket from the 3-device service names lane indexes the small
    // service doesn't even have; the rejection must fire before any
    // lane lookup.
    let t = c_big.submit_alloc(8192).unwrap();
    assert_eq!(c_small.wait(t), Err(AllocError::ForeignTicket));
    assert_eq!(c_small.poll(t), None);
    // And the reverse direction.
    let t2 = c_small.submit_alloc(64).unwrap();
    assert_eq!(c_big.wait(t2), Err(AllocError::ForeignTicket));
    // Both minters still serve their own tickets exactly once.
    let a = c_big.wait(t).unwrap().into_alloc().unwrap();
    c_big.free(a).unwrap();
    let b = c_small.wait(t2).unwrap().into_alloc().unwrap();
    c_small.free(b).unwrap();
}
