//! Protocol model-checking suite — the CI entry point for the
//! bounded-exhaustive explorer over the alloc service's extracted
//! protocol models (`ouroboros_tpu::check`).
//!
//! Five protocols run under exhaustive DFS every push: the TicketRing
//! slot/generation lifecycle, the ForwardingTable forward-exactly-once
//! protocol, the drain quiesce handshake, the device health state
//! machine, and the IndexQueue admission protocol.

use ouroboros_tpu::check::models::{
    DrainModel, ForwardingModel, QueueModel, RingModel, StateMachineModel,
};
use ouroboros_tpu::check::sched::Explorer;

// ---------------------------------------------------------------------------
// Exhaustive passes over the shipped (fixed) protocols
// ---------------------------------------------------------------------------

#[test]
fn ticket_ring_lifecycle_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut RingModel::new())
        .unwrap_or_else(|ce| panic!("ring protocol violated:\n{ce}"));
    assert!(stats.schedules > 0);
    assert_eq!(stats.truncated, 0, "ring schedules must all terminate");
}

#[test]
fn forwarding_table_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut ForwardingModel::fixed())
        .unwrap_or_else(|ce| panic!("forwarding protocol violated:\n{ce}"));
    // 5 threads: this is the widest model; the budget may sample.
    assert!(stats.schedules > 100, "coverage floor: {stats:?}");
}

#[test]
fn drain_quiesce_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut DrainModel::fixed())
        .unwrap_or_else(|ce| panic!("drain protocol violated:\n{ce}"));
    // Blocked-attempt branching (the drainer's spin) inflates the
    // schedule space past the raw step multinomial, so the budget may
    // cap the walk; assert a coverage floor instead of completeness.
    assert!(stats.schedules > 100, "coverage floor: {stats:?}");
    assert_eq!(stats.truncated, 0);
}

#[test]
fn device_state_machine_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut StateMachineModel::new())
        .unwrap_or_else(|ce| panic!("state machine violated:\n{ce}"));
    assert!(!stats.capped, "lifecycle space must be fully enumerated");
}

#[test]
fn index_queue_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut QueueModel::new())
        .unwrap_or_else(|ce| panic!("queue protocol violated:\n{ce}"));
    assert!(stats.schedules > 100, "coverage floor: {stats:?}");
}

// ---------------------------------------------------------------------------
// Seeded-random mode: cheap extra coverage, same replayability
// ---------------------------------------------------------------------------

#[test]
fn random_schedules_pass_on_fixed_protocols() {
    let ex = Explorer::default();
    let seed = 0x5EED_0006;
    ex.random(&mut RingModel::new(), seed, 128)
        .unwrap_or_else(|ce| panic!("ring under random schedules:\n{ce}"));
    ex.random(&mut ForwardingModel::fixed(), seed, 128)
        .unwrap_or_else(|ce| panic!("forwarding under random schedules:\n{ce}"));
    ex.random(&mut DrainModel::fixed(), seed, 128)
        .unwrap_or_else(|ce| panic!("drain under random schedules:\n{ce}"));
    ex.random(&mut StateMachineModel::new(), seed, 128)
        .unwrap_or_else(|ce| panic!("state machine under random schedules:\n{ce}"));
    ex.random(&mut QueueModel::new(), seed, 128)
        .unwrap_or_else(|ce| panic!("queue under random schedules:\n{ce}"));
}
