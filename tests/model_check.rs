//! Protocol model-checking suite — the CI entry point for the
//! bounded-exhaustive explorer over the alloc service's extracted
//! protocol models (`ouroboros_tpu::check`).
//!
//! Eight protocols run under exhaustive DFS every push: the TicketRing
//! slot/generation lifecycle, the ForwardingTable forward-exactly-once
//! protocol, the drain quiesce handshake, the device health state
//! machine, the IndexQueue admission protocol, the federation
//! spill/restart protocol, the client-cache lease serve/recall
//! handshake, and the ring notification-suppression (EVENT_IDX)
//! handshake. The regression half of the suite proves the checker has
//! teeth: the `pre_fix` forwarding model (the PR 5 submit/dispatch
//! TOCTOU), the `buggy` drain ordering, the table-wiping federation
//! restart, the check-recall-before-pin lease TOCTOU, and the
//! watermark-read-before-index-publish lost wakeup all produce
//! replayable counterexamples.

use ouroboros_tpu::check::models::{
    DrainModel, FederationModel, ForwardingModel, LeaseModel, NotifyModel,
    QueueModel, RingModel, StateMachineModel,
};
use ouroboros_tpu::check::sched::Explorer;

// ---------------------------------------------------------------------------
// Exhaustive passes over the shipped (fixed) protocols
// ---------------------------------------------------------------------------

#[test]
fn ticket_ring_lifecycle_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut RingModel::new())
        .unwrap_or_else(|ce| panic!("ring protocol violated:\n{ce}"));
    assert!(stats.schedules > 0);
    assert_eq!(stats.truncated, 0, "ring schedules must all terminate");
}

#[test]
fn forwarding_table_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut ForwardingModel::fixed())
        .unwrap_or_else(|ce| panic!("forwarding protocol violated:\n{ce}"));
    // 5 threads: this is the widest model; the budget may sample.
    assert!(stats.schedules > 100, "coverage floor: {stats:?}");
}

#[test]
fn drain_quiesce_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut DrainModel::fixed())
        .unwrap_or_else(|ce| panic!("drain protocol violated:\n{ce}"));
    // Blocked-attempt branching (the drainer's spin) inflates the
    // schedule space past the raw step multinomial, so the budget may
    // cap the walk; assert a coverage floor instead of completeness.
    assert!(stats.schedules > 100, "coverage floor: {stats:?}");
    assert_eq!(stats.truncated, 0);
}

#[test]
fn device_state_machine_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut StateMachineModel::new())
        .unwrap_or_else(|ce| panic!("state machine violated:\n{ce}"));
    assert!(!stats.capped, "lifecycle space must be fully enumerated");
}

#[test]
fn federation_protocol_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut FederationModel::fixed())
        .unwrap_or_else(|ce| panic!("federation protocol violated:\n{ce}"));
    assert!(stats.schedules > 0);
    assert_eq!(
        stats.truncated, 0,
        "federation schedules must all terminate"
    );
}

#[test]
fn lease_serve_recall_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut LeaseModel::fixed())
        .unwrap_or_else(|ce| panic!("lease protocol violated:\n{ce}"));
    assert!(stats.schedules > 0);
    // The recaller's pin-quiesce spin branches on Blocked attempts,
    // like the drain model; assert termination, not completeness.
    assert_eq!(stats.truncated, 0, "lease schedules must all terminate");
}

#[test]
fn notify_suppression_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut NotifyModel::fixed())
        .unwrap_or_else(|ce| panic!("notify protocol violated:\n{ce}"));
    assert!(stats.schedules > 0);
    // The waiter's condvar park branches on Blocked attempts; assert
    // termination, not completeness.
    assert_eq!(stats.truncated, 0, "notify schedules must all terminate");
}

#[test]
fn index_queue_exhaustive() {
    let stats = Explorer::default()
        .exhaustive(&mut QueueModel::new())
        .unwrap_or_else(|ce| panic!("queue protocol violated:\n{ce}"));
    assert!(stats.schedules > 100, "coverage floor: {stats:?}");
}

// ---------------------------------------------------------------------------
// Seeded-random mode: cheap extra coverage, same replayability
// ---------------------------------------------------------------------------

#[test]
fn random_schedules_pass_on_fixed_protocols() {
    let ex = Explorer::default();
    let seed = 0x5EED_0006;
    ex.random(&mut RingModel::new(), seed, 128)
        .unwrap_or_else(|ce| panic!("ring under random schedules:\n{ce}"));
    ex.random(&mut ForwardingModel::fixed(), seed, 128)
        .unwrap_or_else(|ce| panic!("forwarding under random schedules:\n{ce}"));
    ex.random(&mut DrainModel::fixed(), seed, 128)
        .unwrap_or_else(|ce| panic!("drain under random schedules:\n{ce}"));
    ex.random(&mut StateMachineModel::new(), seed, 128)
        .unwrap_or_else(|ce| panic!("state machine under random schedules:\n{ce}"));
    ex.random(&mut QueueModel::new(), seed, 128)
        .unwrap_or_else(|ce| panic!("queue under random schedules:\n{ce}"));
    ex.random(&mut FederationModel::fixed(), seed, 128)
        .unwrap_or_else(|ce| panic!("federation under random schedules:\n{ce}"));
    ex.random(&mut LeaseModel::fixed(), seed, 128)
        .unwrap_or_else(|ce| panic!("lease under random schedules:\n{ce}"));
    ex.random(&mut NotifyModel::fixed(), seed, 128)
        .unwrap_or_else(|ce| panic!("notify under random schedules:\n{ce}"));
}

// ---------------------------------------------------------------------------
// Regressions: the checker must find the bugs the fixes removed
// ---------------------------------------------------------------------------

/// The PR 5 forwarding-grace TOCTOU: submit probed the forwarding
/// entry without consuming it, dispatch re-derived the verdict — so a
/// grace expiry (or the racing stale free) between the two probes
/// turned an accepted free into a dispatch-time rejection and leaked
/// the migrated copy. The fix pins the verdict with a consume-at-submit
/// CAS; this test proves the checker catches the old logic.
#[test]
fn pre_fix_forwarding_toctou_is_caught() {
    let ce = Explorer::default()
        .exhaustive(&mut ForwardingModel::pre_fix())
        .expect_err("the submit/dispatch TOCTOU must be found");
    assert!(
        ce.error.contains("rejected at dispatch"),
        "unexpected counterexample:\n{ce}"
    );
    assert!(ce.error.contains("leaked"), "{ce}");

    // The counterexample is a real schedule: replaying it reproduces
    // the identical failure, step for step.
    let again = Explorer::replay(&mut ForwardingModel::pre_fix(), &ce.schedule)
        .expect_err("replay must reproduce the TOCTOU");
    assert_eq!(again.error, ce.error);
    assert_eq!(again.schedule, ce.schedule);
    assert_eq!(again.trace, ce.trace);

    // And the fixed protocol survives the exact same schedule.
    Explorer::replay(&mut ForwardingModel::fixed(), &ce.schedule)
        .unwrap_or_else(|ce| panic!("fixed protocol failed the TOCTOU schedule:\n{ce}"));
}

#[test]
fn pre_fix_forwarding_toctou_found_by_random_too() {
    let ce = Explorer::default()
        .random(&mut ForwardingModel::pre_fix(), 0xC0FFEE_06, 512)
        .expect_err("512 random schedules must hit the TOCTOU window");
    assert!(ce.error.contains("rejected at dispatch"), "{ce}");
}

/// Check-health-then-raise-gauge (the order the SeqCst drain handshake
/// exists to forbid): an allocation can pass the health check, stall,
/// and place its block after the drainer enumerated the live set.
#[test]
fn buggy_drain_ordering_is_caught_and_replayable() {
    let ce = Explorer::default()
        .exhaustive(&mut DrainModel::buggy())
        .expect_err("check-then-raise must lose a block past enumeration");
    assert!(ce.error.contains("slipped past enumeration"), "{ce}");

    let again = Explorer::replay(&mut DrainModel::buggy(), &ce.schedule)
        .expect_err("replay must reproduce the slipped alloc");
    assert_eq!(again.error, ce.error);
    // (No cross-replay against the fixed model here: the two modes
    // have different per-thread step counts, so a buggy-mode schedule
    // is not necessarily well-formed for the fixed protocol. The
    // forwarding TOCTOU test covers cross-mode replay, where the step
    // shapes do align.)
}

/// A group restart that comes back with an empty name table (the bug
/// the `OUROSNAP` durable snapshot exists to prevent): any schedule
/// interleaving the restart between an alloc and its tag-routed free
/// loses the block. The fixed protocol — restore-from-handoff — must
/// survive the exact counterexample schedule.
#[test]
fn restart_wiping_forwarding_table_is_caught() {
    let ce = Explorer::default()
        .exhaustive(&mut FederationModel::buggy())
        .expect_err("a table-wiping restart must lose a block");
    assert!(ce.error.contains("lost"), "unexpected counterexample:\n{ce}");

    let again = Explorer::replay(&mut FederationModel::buggy(), &ce.schedule)
        .expect_err("replay must reproduce the lost block");
    assert_eq!(again.error, ce.error);
    assert_eq!(again.schedule, ce.schedule);

    // Same step shapes in both modes, so the schedule is well-formed
    // for the fixed protocol — which must survive it.
    Explorer::replay(&mut FederationModel::fixed(), &ce.schedule)
        .unwrap_or_else(|ce| {
            panic!("restore-from-handoff failed the wipe schedule:\n{ce}")
        });
}

/// The lease cache's check-recall-before-pin TOCTOU (the ordering the
/// SeqCst pin handshake exists to forbid): the owner probes the recall
/// flag with no pin held, the recaller latches + sees zero pins +
/// migrates the span in the window, and the owner then serves a block
/// out of storage that has already moved.
#[test]
fn buggy_lease_recall_check_is_caught_and_replayable() {
    let ce = Explorer::default()
        .exhaustive(&mut LeaseModel::buggy())
        .expect_err("check-before-pin must serve from a migrated span");
    assert!(
        ce.error.contains("after its migration"),
        "unexpected counterexample:\n{ce}"
    );

    let again = Explorer::replay(&mut LeaseModel::buggy(), &ce.schedule)
        .expect_err("replay must reproduce the recalled-span serve");
    assert_eq!(again.error, ce.error);
    assert_eq!(again.schedule, ce.schedule);
    assert_eq!(again.trace, ce.trace);
    // (No cross-replay against the fixed mode: like the drain model,
    // the two modes order pin and check differently, so a buggy-mode
    // schedule is not necessarily well-formed for the fixed protocol.)
}

/// The lost wakeup the EVENT_IDX discipline's ordering exists to
/// forbid: a completer that caches its suppress-or-deliver verdict
/// *before* publishing the used index leaves a stale-read window — a
/// waiter can register, publish its watermark, re-check, and park
/// entirely inside it, and the cached "nobody is waiting" verdict then
/// suppresses the only broadcast that would ever wake it. The model
/// flags the suppression-with-a-parked-waiter state directly, the
/// schedule replays deterministically, and the shipped
/// publish-then-read protocol survives the exact same schedule (both
/// modes share per-thread step shapes).
#[test]
fn buggy_notify_suppression_is_caught_and_replayable() {
    let ce = Explorer::default()
        .exhaustive(&mut NotifyModel::buggy())
        .expect_err("watermark-before-publish must park a waiter forever");
    assert!(
        ce.error.contains("lost wakeup"),
        "unexpected counterexample:\n{ce}"
    );

    let again = Explorer::replay(&mut NotifyModel::buggy(), &ce.schedule)
        .expect_err("replay must reproduce the lost wakeup");
    assert_eq!(again.error, ce.error);
    assert_eq!(again.schedule, ce.schedule);
    assert_eq!(again.trace, ce.trace);

    // Publish-then-read survives the exact schedule: either the read
    // sees the registration (broadcast delivered) or the waiter's
    // re-check sees the published completion.
    Explorer::replay(&mut NotifyModel::fixed(), &ce.schedule)
        .unwrap_or_else(|ce| {
            panic!("fixed notify protocol failed the lost-wakeup schedule:\n{ce}")
        });
}

#[test]
fn buggy_notify_suppression_found_by_random_too() {
    let ce = Explorer::default()
        .random(&mut NotifyModel::buggy(), 0xC0FFEE_09, 512)
        .expect_err("512 random schedules must hit the stale-read window");
    assert!(ce.error.contains("lost wakeup"), "{ce}");
}

/// Counterexample traces are printable artifacts: one line per step,
/// carrying thread ids and the model's own step descriptions.
#[test]
fn counterexample_trace_is_renderable() {
    let ce = Explorer::default()
        .exhaustive(&mut ForwardingModel::pre_fix())
        .expect_err("needed a counterexample to render");
    assert_eq!(ce.trace.len(), ce.schedule.len());
    let rendered = format!("{ce}");
    assert!(rendered.contains("invariant violated"), "{rendered}");
    assert!(rendered.contains("schedule (replayable)"), "{rendered}");
    assert!(rendered.contains("#000"), "trace lines numbered: {rendered}");
}
