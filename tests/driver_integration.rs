//! Driver integration: the paper's benchmark loop across the full
//! (variant x backend) matrix, with data-phase verification.

use std::sync::Arc;

use ouroboros_tpu::coordinator::driver::{run_driver, DataPhase, DriverConfig};
use ouroboros_tpu::harness::figures::backend_device_pairs;
use ouroboros_tpu::ouroboros::{HeapConfig, Variant};
use ouroboros_tpu::simt::{Device, DeviceProfile};

fn cfg(variant: Variant, threads: u32) -> DriverConfig {
    DriverConfig {
        variant,
        alloc_size: 1000,
        num_allocations: threads,
        iterations: 3,
        data_phase: DataPhase::Sim,
        heap: HeapConfig::default(),
        seed: 11,
    }
}

/// Every variant on every backend completes the full loop with data
/// verification, no allocation failures, and positive timings.
#[test]
fn full_matrix_verifies() {
    for variant in Variant::all() {
        for (be, profile) in backend_device_pairs() {
            let device = Device::new(profile, be.clone());
            let rep = run_driver(&device, &cfg(variant, 256), None)
                .unwrap_or_else(|e| panic!("{} x {}: {e}", variant.id(), be.id()));
            assert!(
                rep.verify_ok(),
                "{} x {}: data verification failed",
                variant.id(),
                be.id()
            );
            assert_eq!(
                rep.iters.iter().map(|i| i.alloc_failures).sum::<u32>(),
                0,
                "{} x {}: allocation failures",
                variant.id(),
                be.id()
            );
            assert!(rep.alloc_split().mean_subsequent > 0.0);
            assert!(rep.free_split().mean_subsequent > 0.0);
        }
    }
}

/// The §3 Methods observation: JIT backends show first >> subsequent;
/// AOT CUDA does not.
#[test]
fn jit_split_shape() {
    for (be, profile) in backend_device_pairs() {
        let device = Device::new(profile, be.clone());
        let rep = run_driver(&device, &cfg(Variant::Page, 512), None).unwrap();
        let s = rep.alloc_split();
        let has_jit = be.costs().jit_warmup_us > 0.0;
        if has_jit {
            assert!(
                s.first > 3.0 * s.mean_subsequent,
                "{}: JIT first-iteration spike missing ({s:?})",
                be.id()
            );
        } else {
            assert!(
                s.first < 3.0 * s.mean_subsequent.max(1e-9),
                "{}: unexpected first-iteration spike ({s:?})",
                be.id()
            );
        }
    }
}

/// Larger launches must not be cheaper in total time (sanity of the
/// serialization model).
#[test]
fn total_time_monotone_in_threads() {
    for variant in [Variant::Page, Variant::Chunk] {
        let device = Device::new(
            DeviceProfile::t2000(),
            Arc::new(ouroboros_tpu::backend::Cuda::new()),
        );
        let t_small = run_driver(&device, &cfg(variant, 128), None)
            .unwrap()
            .alloc_split()
            .mean_subsequent;
        let t_large = run_driver(&device, &cfg(variant, 4096), None)
            .unwrap()
            .alloc_split()
            .mean_subsequent;
        assert!(
            t_large > t_small,
            "{}: 4096-thread launch ({t_large}) not slower than 128 \
             ({t_small})",
            variant.id()
        );
    }
}

/// The acpp pathology is thread-count gated: quiet at 256, visible at
/// 4096 (paper §4 note).
#[test]
fn acpp_pathology_gated_by_scale() {
    let device = Device::new(
        DeviceProfile::t2000(),
        Arc::new(ouroboros_tpu::backend::Acpp::new()),
    );
    let quiet = run_driver(&device, &cfg(Variant::Chunk, 256), None).unwrap();
    assert!(!quiet.any_timeout(), "acpp should be fine at 256 threads");
    assert_eq!(quiet.total_deadlocks(), 0);

    let loud = run_driver(&device, &cfg(Variant::Chunk, 4096), None).unwrap();
    assert!(
        loud.any_timeout() && loud.total_deadlocks() > 0,
        "acpp pathology missing at 4096 threads"
    );
    // Correctness still holds — the simulator completes serially.
    assert!(loud.verify_ok());
}

/// Free times are also measured (the paper reports alloc and free).
#[test]
fn free_phase_measured_and_heap_drained() {
    let device = Device::new(
        DeviceProfile::t2000(),
        Arc::new(ouroboros_tpu::backend::Cuda::new()),
    );
    for variant in Variant::all() {
        let rep = run_driver(&device, &cfg(variant, 512), None).unwrap();
        for it in &rep.iters {
            assert!(it.free_us > 0.0);
        }
    }
}

/// Mixed-size driver runs (not part of the paper's sweep, but the
/// allocator must handle non-uniform warp requests).
#[test]
fn non_uniform_sizes_within_warp() {
    use ouroboros_tpu::ouroboros::allocator::{warp_free, warp_malloc};
    use ouroboros_tpu::ouroboros::build_allocator;
    use ouroboros_tpu::simt::Grid;

    let device = Device::new(
        DeviceProfile::t2000(),
        Arc::new(ouroboros_tpu::backend::Cuda::new()),
    );
    let alloc = build_allocator(Variant::Chunk, &HeapConfig::default());
    let alloc2 = alloc.clone();
    let st = device.launch("mixed", Grid::new(64), move |w| {
        let lanes: Vec<u32> = w.active_lanes().collect();
        let sizes: Vec<u32> = lanes
            .iter()
            .map(|&l| 16 << (w.thread_id(l) % 10))
            .collect();
        let rs = warp_malloc(alloc2.as_ref(), w, &sizes);
        assert!(rs.iter().all(|r| r.is_ok()));
        let addrs: Vec<Option<u32>> =
            rs.iter().map(|r| r.as_ref().ok().copied()).collect();
        for r in warp_free(alloc2.as_ref(), w, &addrs) {
            r.unwrap();
        }
    });
    assert!(!st.timed_out);
    assert!(alloc.debug_consistent());
}
