//! The reproduction gate: measure quick versions of Figures 1 and 2 and
//! assert every §4/§5 claim of the paper holds on this substrate
//! (DESIGN.md §6 "expected shapes"). The full-axis version runs via
//! `cargo bench` / `ouroboros-tpu claims`.

use ouroboros_tpu::harness::{expectations, figures};

#[test]
fn paper_claims_hold_on_quick_sweep() {
    let opts = figures::SweepOpts {
        quick: true,
        iterations: 3,
        heap: Default::default(),
    };
    let f1 = figures::run_figure(1, &opts).expect("figure 1");
    let f2 = figures::run_figure(2, &opts).expect("figure 2");
    let claims = expectations::standard_claims(&f1, &f2);
    let report = expectations::render_claims(&claims);
    println!("{report}");
    let failed: Vec<_> = claims.iter().filter(|c| !c.holds).collect();
    assert!(
        failed.is_empty(),
        "paper claims failed:\n{report}"
    );

    // Every measured point also passed data verification.
    for fig in [&f1, &f2] {
        for s in fig.left.iter().chain(fig.right.iter()) {
            assert!(s.points.iter().all(|p| p.verify_ok));
        }
    }
}
