//! Allocation-service integration + failure injection.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use ouroboros_tpu::backend::{Acpp, Cuda};
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::driver::run_service_trace;
use ouroboros_tpu::coordinator::ring::Completion;
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::coordinator::workload::rolling_trace;
use ouroboros_tpu::ouroboros::{
    build_allocator, AllocError, GlobalAddr, HeapConfig, Variant,
};
use ouroboros_tpu::simt::{Device, DeviceProfile};

fn service(variant: Variant, chunks: u32) -> AllocService {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    let alloc = build_allocator(
        variant,
        &HeapConfig { num_chunks: chunks, ..HeapConfig::default() },
    );
    AllocService::start(device, alloc, BatchPolicy::default())
}

#[test]
fn churn_through_service_drains_clean() {
    let svc = service(Variant::VlChunk, 256);
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let c = svc.client();
            s.spawn(move || {
                let mut live = Vec::new();
                for i in 0..150u64 {
                    let size = 16 + ((t * 131 + i * 97) % 4000) as u32;
                    live.push(c.alloc(size).unwrap());
                    if i % 2 == 1 {
                        let a = live.remove((i as usize) % live.len());
                        c.free(a).unwrap();
                    }
                }
                for a in live {
                    c.free(a).unwrap();
                }
            });
        }
    });
    let alloc = svc.allocator().clone();
    drop(svc);
    assert!(alloc.debug_consistent());
    assert_eq!(
        alloc.counters().mallocs.load(Ordering::Relaxed),
        alloc.counters().frees.load(Ordering::Relaxed)
    );
}

#[test]
fn invalid_requests_surface_as_errors_not_crashes() {
    let svc = service(Variant::Page, 64);
    let c = svc.client();
    assert_eq!(c.alloc(0), Err(AllocError::ZeroSize));
    assert_eq!(c.alloc(100_000), Err(AllocError::TooLarge(100_000)));
    // Wild / double frees.
    assert!(matches!(
        c.free(GlobalAddr::from_raw(0xDEAD_0000)),
        Err(AllocError::InvalidFree(_))
    ));
    let a = c.alloc(500).unwrap();
    c.free(a).unwrap();
    assert!(matches!(c.free(a), Err(AllocError::InvalidFree(_))));
    // The service keeps working after failed requests.
    let b = c.alloc(500).unwrap();
    c.free(b).unwrap();
}

#[test]
fn heap_exhaustion_recovers_after_frees() {
    let svc = service(Variant::Chunk, 8); // 8 chunks = 64 KiB
    let c = svc.client();
    let mut live = Vec::new();
    loop {
        match c.alloc(8192) {
            Ok(a) => live.push(a),
            Err(AllocError::OutOfMemory) => break,
            Err(e) => panic!("unexpected {e}"),
        }
    }
    assert_eq!(live.len(), 8);
    // Free two, and the service can allocate again.
    c.free(live.pop().unwrap()).unwrap();
    c.free(live.pop().unwrap()).unwrap();
    let again = c.alloc(8192).expect("recovered after frees");
    c.free(again).unwrap();
    for a in live {
        c.free(a).unwrap();
    }
}

#[test]
fn batching_coalesces_bursts() {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    let alloc = build_allocator(Variant::Page, &HeapConfig::default());
    let svc = AllocService::start(
        device,
        alloc,
        BatchPolicy {
            max_batch: 32,
            window: Duration::from_millis(5),
            ..Default::default()
        },
    );
    std::thread::scope(|s| {
        for _ in 0..16 {
            let c = svc.client();
            s.spawn(move || {
                let mut mine = Vec::new();
                for _ in 0..20 {
                    mine.push(c.alloc(256).unwrap());
                }
                for a in mine {
                    c.free(a).unwrap();
                }
            });
        }
    });
    let mean_batch = svc.stats().mean_batch();
    assert!(
        mean_batch > 1.5,
        "16 bursty clients should coalesce (mean batch {mean_batch})"
    );
}

/// Cross-client property test: randomized interleaved alloc/free from 8
/// client threads, asserting no duplicate live addresses (via a global
/// live-set registry), double-free detection at quiesce, balanced
/// counters, and `debug_consistent()` after drain. Exercised across a
/// page and a chunk variant so both bulk paths (`bulk_free` /
/// `bulk_step`) see concurrent sharded traffic.
#[test]
fn cross_client_randomized_churn_property() {
    use ouroboros_tpu::util::rng::Rng;
    use std::collections::HashSet;
    use std::sync::Mutex;

    for variant in [Variant::Page, Variant::VlChunk] {
        let svc = service(variant, 512);
        // Every address currently handed out, across all clients. An
        // insert that finds the address already present means the
        // service double-allocated live memory.
        let live_global: Mutex<HashSet<GlobalAddr>> =
            Mutex::new(HashSet::new());
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let c = svc.client();
                let live_global = &live_global;
                s.spawn(move || {
                    let mut rng = Rng::new(0xC11E27 + t);
                    let mut mine: Vec<GlobalAddr> = Vec::new();
                    for _ in 0..150 {
                        let do_alloc = mine.is_empty() || rng.chance(0.55);
                        if do_alloc {
                            let size = rng.range(1, 8192) as u32;
                            let addr = c.alloc(size).unwrap_or_else(|e| {
                                panic!("{}: alloc({size}): {e}", variant.id())
                            });
                            assert!(
                                live_global.lock().unwrap().insert(addr),
                                "{}: duplicate live address {addr}",
                                variant.id()
                            );
                            mine.push(addr);
                        } else {
                            let i = rng.below(mine.len() as u64) as usize;
                            let addr = mine.swap_remove(i);
                            assert!(
                                live_global.lock().unwrap().remove(&addr),
                                "{}: freed address not in live set",
                                variant.id()
                            );
                            c.free(addr).unwrap_or_else(|e| {
                                panic!("{}: free({addr}): {e}", variant.id())
                            });
                        }
                    }
                    for addr in mine {
                        live_global.lock().unwrap().remove(&addr);
                        c.free(addr).unwrap();
                    }
                });
            }
        });
        assert!(live_global.lock().unwrap().is_empty());

        // Every churn alloc was matched by a free through the service
        // (read through the plain-value snapshot rather than raw
        // atomics).
        let snap = svc.snapshot();
        assert_eq!(
            snap.allocs,
            snap.frees,
            "{}: service alloc/free op counts unbalanced",
            variant.id()
        );
        assert!(snap.mean_batch >= 1.0, "{}: {snap:?}", variant.id());

        // Quiesce: double frees are detected, not absorbed.
        let c = svc.client();
        let probe = c.alloc(777).unwrap();
        c.free(probe).unwrap();
        assert!(
            matches!(c.free(probe), Err(AllocError::InvalidFree(_))),
            "{}: double free undetected at quiesce",
            variant.id()
        );

        let alloc = svc.allocator().clone();
        drop(svc);
        assert!(alloc.debug_consistent(), "{}", variant.id());
        assert_eq!(
            alloc.counters().mallocs.load(Ordering::Relaxed),
            alloc.counters().frees.load(Ordering::Relaxed),
            "{}: allocator counters unbalanced after drain",
            variant.id()
        );
    }
}

/// Requests racing a shutdown surface `ServiceDown`, never the
/// heap-corruption error the seed used to masquerade behind.
#[test]
fn shutdown_reports_service_down() {
    let svc = service(Variant::Page, 64);
    let c = svc.client();
    let a = c.alloc(100).unwrap();
    c.free(a).unwrap();
    svc.shutdown();
    assert_eq!(c.alloc(100), Err(AllocError::ServiceDown));
    assert_eq!(c.free(a), Err(AllocError::ServiceDown));
}

/// The sharded lanes partition traffic by size class and the per-lane
/// counters add up to the aggregates.
#[test]
fn sharded_lanes_partition_traffic() {
    let svc = service(Variant::Page, 256);
    std::thread::scope(|s| {
        for t in 0..4u32 {
            let c = svc.client();
            s.spawn(move || {
                // Each thread hammers one distinct class: 16 B (q0),
                // 100 B (q3), 1000 B (q6), 8192 B (q9).
                let size = [16u32, 100, 1000, 8192][t as usize];
                for _ in 0..25 {
                    let a = c.alloc(size).unwrap();
                    c.free(a).unwrap();
                }
            });
        }
    });
    let snap = svc.snapshot();
    let lanes = &snap.lane_batches;
    for q in [0usize, 3, 6, 9] {
        assert!(lanes[q] > 0, "lane {q} idle: {lanes:?}");
    }
    for q in [1usize, 2, 4, 5, 7, 8] {
        assert_eq!(lanes[q], 0, "lane {q} saw foreign traffic: {lanes:?}");
    }
    assert_eq!(lanes.iter().sum::<u64>(), snap.batches);
    assert_eq!(snap.lane_ops.iter().sum::<u64>(), snap.ops);
    // The single-device group rolls everything up to one device entry.
    assert_eq!(snap.devices.len(), 1);
    assert_eq!(snap.devices[0].ops, snap.ops);
}

/// The async ticket pipeline end to end: one client thread keeps a lane
/// batch full by submitting at depth; every ticket resolves exactly
/// once; the allocator drains clean.
#[test]
fn async_pipeline_single_client_keeps_batches_full() {
    let svc = service(Variant::Page, 256);
    let c = svc.client();
    let rep = run_service_trace(&c, &rolling_trace(64, 500, 1000), 32).unwrap();
    assert_eq!(rep.allocs, 500);
    assert_eq!(rep.frees, 500);
    assert_eq!(rep.alloc_failures, 0);
    assert_eq!(rep.max_inflight, 32);
    // The single-threaded pipeline produced multi-op device batches —
    // the effect blocking clients need many threads to get.
    assert!(
        svc.stats().mean_batch() > 1.5,
        "depth-32 pipeline should coalesce (mean batch {})",
        svc.stats().mean_batch()
    );
    assert!(svc.ring_high_water().iter().any(|&h| h >= 16));
    assert!(svc.stats().mean_depth() > 2.0);
    let alloc = svc.allocator().clone();
    drop(svc);
    assert!(alloc.debug_consistent());
    assert_eq!(
        alloc.counters().mallocs.load(Ordering::Relaxed),
        alloc.counters().frees.load(Ordering::Relaxed)
    );
}

/// Async and blocking clients share lanes safely.
#[test]
fn async_and_blocking_clients_interleave() {
    let svc = service(Variant::VlChunk, 256);
    std::thread::scope(|s| {
        // Two pipelined clients...
        for _ in 0..2 {
            let c = svc.client();
            s.spawn(move || {
                let rep =
                    run_service_trace(&c, &rolling_trace(16, 150, 500), 16)
                        .unwrap();
                assert_eq!(rep.alloc_failures, 0);
            });
        }
        // ...racing two blocking clients on the same classes.
        for _ in 0..2 {
            let c = svc.client();
            s.spawn(move || {
                for _ in 0..100 {
                    let a = c.alloc(500).unwrap();
                    c.free(a).unwrap();
                }
            });
        }
    });
    let alloc = svc.allocator().clone();
    drop(svc);
    assert!(alloc.debug_consistent());
}

/// Out-of-heap frees are rejected at submit (counted, never batched);
/// in-heap double frees still travel to the device and come back as
/// `InvalidFree` completions.
#[test]
fn invalid_free_rejected_at_submit_not_lane_zero() {
    let svc = service(Variant::Page, 64);
    let c = svc.client();
    // Drive lane 0 once so we know its batch counter works, then
    // quiesce.
    let a = c.alloc(16).unwrap();
    c.free(a).unwrap();
    let lane0_batches = svc.stats().lane_batches()[0];
    assert!(lane0_batches > 0);

    let wild = GlobalAddr::from_raw(64 * 8192 + 16); // past the 64-chunk heap
    assert_eq!(
        c.submit_free(wild).unwrap_err(),
        AllocError::InvalidFree(wild.raw())
    );
    assert_eq!(svc.stats().invalid_frees.load(Ordering::Relaxed), 1);
    // The rejected free never became a lane-0 batch.
    assert_eq!(svc.stats().lane_batches()[0], lane0_batches);

    // Double free of an in-heap address: a real device-side InvalidFree,
    // delivered through the completion ring.
    let b = c.alloc(1000).unwrap();
    c.free(b).unwrap();
    let t = c.submit_free(b).unwrap();
    match c.wait(t).unwrap() {
        Completion::Free(r) => {
            assert!(matches!(r, Err(AllocError::InvalidFree(_))))
        }
        other => panic!("free ticket completed as {other:?}"),
    }
}

/// A timed-out (acpp) device still completes requests — the watchdog
/// surfaces in timing, not correctness (the paper could still verify
/// data on the runs that finished).
#[test]
fn acpp_service_still_correct() {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Acpp::new()));
    let alloc = build_allocator(Variant::Page, &HeapConfig::default());
    let svc = AllocService::start(device, alloc, BatchPolicy::default());
    let c = svc.client();
    let addrs: Vec<GlobalAddr> =
        (0..64).map(|_| c.alloc(777).unwrap()).collect();
    let mut uniq = addrs.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), addrs.len());
    for a in addrs {
        c.free(a).unwrap();
    }
}
