//! Allocation-service integration + failure injection.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use ouroboros_tpu::backend::{Acpp, Cuda};
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::ouroboros::{
    build_allocator, AllocError, HeapConfig, Variant,
};
use ouroboros_tpu::simt::{Device, DeviceProfile};

fn service(variant: Variant, chunks: u32) -> AllocService {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    let alloc = build_allocator(
        variant,
        &HeapConfig { num_chunks: chunks, ..HeapConfig::default() },
    );
    AllocService::start(device, alloc, BatchPolicy::default())
}

#[test]
fn churn_through_service_drains_clean() {
    let svc = service(Variant::VlChunk, 256);
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let c = svc.client();
            s.spawn(move || {
                let mut live = Vec::new();
                for i in 0..150u64 {
                    let size = 16 + ((t * 131 + i * 97) % 4000) as u32;
                    live.push(c.alloc(size).unwrap());
                    if i % 2 == 1 {
                        let a = live.remove((i as usize) % live.len());
                        c.free(a).unwrap();
                    }
                }
                for a in live {
                    c.free(a).unwrap();
                }
            });
        }
    });
    let alloc = svc.allocator().clone();
    drop(svc);
    assert!(alloc.debug_consistent());
    assert_eq!(
        alloc.counters().mallocs.load(Ordering::Relaxed),
        alloc.counters().frees.load(Ordering::Relaxed)
    );
}

#[test]
fn invalid_requests_surface_as_errors_not_crashes() {
    let svc = service(Variant::Page, 64);
    let c = svc.client();
    assert_eq!(c.alloc(0), Err(AllocError::ZeroSize));
    assert_eq!(c.alloc(100_000), Err(AllocError::TooLarge(100_000)));
    // Wild / double frees.
    assert!(matches!(c.free(0xDEAD_0000), Err(AllocError::InvalidFree(_))));
    let a = c.alloc(500).unwrap();
    c.free(a).unwrap();
    assert!(matches!(c.free(a), Err(AllocError::InvalidFree(_))));
    // The service keeps working after failed requests.
    let b = c.alloc(500).unwrap();
    c.free(b).unwrap();
}

#[test]
fn heap_exhaustion_recovers_after_frees() {
    let svc = service(Variant::Chunk, 8); // 8 chunks = 64 KiB
    let c = svc.client();
    let mut live = Vec::new();
    loop {
        match c.alloc(8192) {
            Ok(a) => live.push(a),
            Err(AllocError::OutOfMemory) => break,
            Err(e) => panic!("unexpected {e}"),
        }
    }
    assert_eq!(live.len(), 8);
    // Free two, and the service can allocate again.
    c.free(live.pop().unwrap()).unwrap();
    c.free(live.pop().unwrap()).unwrap();
    let again = c.alloc(8192).expect("recovered after frees");
    c.free(again).unwrap();
    for a in live {
        c.free(a).unwrap();
    }
}

#[test]
fn batching_coalesces_bursts() {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    let alloc = build_allocator(Variant::Page, &HeapConfig::default());
    let svc = AllocService::start(
        device,
        alloc,
        BatchPolicy { max_batch: 32, window: Duration::from_millis(5) },
    );
    std::thread::scope(|s| {
        for _ in 0..16 {
            let c = svc.client();
            s.spawn(move || {
                let mut mine = Vec::new();
                for _ in 0..20 {
                    mine.push(c.alloc(256).unwrap());
                }
                for a in mine {
                    c.free(a).unwrap();
                }
            });
        }
    });
    let mean_batch = svc.stats().mean_batch();
    assert!(
        mean_batch > 1.5,
        "16 bursty clients should coalesce (mean batch {mean_batch})"
    );
}

/// A timed-out (acpp) device still completes requests — the watchdog
/// surfaces in timing, not correctness (the paper could still verify
/// data on the runs that finished).
#[test]
fn acpp_service_still_correct() {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Acpp::new()));
    let alloc = build_allocator(Variant::Page, &HeapConfig::default());
    let svc = AllocService::start(device, alloc, BatchPolicy::default());
    let c = svc.client();
    let addrs: Vec<u32> = (0..64).map(|_| c.alloc(777).unwrap()).collect();
    let mut uniq = addrs.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), addrs.len());
    for a in addrs {
        c.free(a).unwrap();
    }
}
