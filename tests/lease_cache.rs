//! Client-side lease cache chaos suite — cached alloc/free round
//! trips, cross-client delayed frees, lease recall under a mid-churn
//! drain, hard-retire stranding, the readmit window guard, and cached
//! handles across a federation group restart.
//!
//! `OURO_CHAOS_SEEDS` (default 2) controls how many RNG seeds the
//! randomized tests loop; CI runs this file at 8 seeds, and the
//! analysis job re-runs it under `OURO_SAN=1` so every lease carve,
//! cached free and recall is double-entry bookkept by the shadow heap,
//! and under `OURO_LIN=1` so every seed's recorded history linearizes
//! (see `common::check_history`).

mod common;

use std::collections::HashSet;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::driver::failover_quiesce_timeout;
use ouroboros_tpu::coordinator::federation::{
    FederationClient, FederationRouter,
};
use ouroboros_tpu::coordinator::router::{DeviceState, RoutePolicy};
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::ouroboros::{
    AllocError, GlobalAddr, HeapConfig, Variant,
};
use ouroboros_tpu::util::rng::Rng;

fn chaos_seeds() -> u64 {
    std::env::var("OURO_CHAOS_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2)
        .max(1)
}

/// The same heterogeneous 3-device group the failover suite churns:
/// two t2000s around an Iris Xe, each member a different allocator
/// variant over its own heap.
fn hetero_group(route: RoutePolicy) -> AllocService {
    AllocService::start_named_group(
        &[
            ("t2000", Variant::Page),
            ("iris-xe", Variant::Chunk),
            ("t2000", Variant::VlChunk),
        ],
        &HeapConfig { num_chunks: 512, ..HeapConfig::default() },
        BatchPolicy::default(),
        route,
        Arc::new(Cuda::new()),
    )
}

fn quiesce_then_retire(svc: &AllocService, victim: usize) {
    svc.wait_lanes_quiet(victim, failover_quiesce_timeout());
    svc.retire_device(victim);
}

/// Single cached client, deterministic round trip: every alloc of a
/// cacheable class is served from a lease (zero ring traffic beyond
/// the span mints), every owner free lands back on the local list,
/// and the flush returns every lease — the service-side registry ends
/// empty and ring-level allocs balance ring-level frees.
#[test]
fn cached_roundtrip_returns_every_lease() {
    let svc = hetero_group(RoutePolicy::RoundRobin);
    let c = svc.client();
    c.set_caching(true);
    assert!(c.caching_enabled());

    let mut rng = Rng::new(0x1EA5E);
    let mut addrs = Vec::new();
    let mut uniq = HashSet::new();
    for _ in 0..120 {
        let size = rng.range(1, 4096) as u32;
        let a = c.alloc(size).expect("cached alloc");
        assert!(uniq.insert(a), "duplicate cached address {a}");
        addrs.push(a);
    }
    let stats = svc.stats();
    assert_eq!(
        stats.cached_allocs.load(Ordering::Relaxed),
        120,
        "every cacheable-class alloc must be served from a lease"
    );
    let mints = stats.lease_mints.load(Ordering::Relaxed);
    assert!(mints >= 1, "serving 120 blocks takes at least one span");
    assert!(c.cached_spans() >= 1);

    for a in addrs {
        c.free(a).expect("owner free");
    }
    c.flush_cache();
    assert_eq!(svc.live_leases(), 0, "flush must return every lease");
    assert_eq!(
        stats.lease_returns.load(Ordering::Relaxed),
        stats.lease_mints.load(Ordering::Relaxed),
        "every minted span must come back"
    );

    let snap = svc.snapshot();
    assert_eq!(snap.allocs, snap.frees, "ring-level leak: {snap:?}");
    assert_eq!(
        snap.cached_latency.count, 240,
        "120 cached allocs + 120 cached frees in the histogram"
    );
    assert!(snap.ring_latency.count > 0, "span mints cross the ring");
    common::check_history(&svc.history());

    // Disarming flushes and falls back to the ring path bit-for-bit.
    c.set_caching(false);
    assert!(!c.caching_enabled());
    let a = c.alloc(64).expect("ring alloc after disarm");
    c.free(a).expect("ring free after disarm");
    assert_eq!(stats.cached_allocs.load(Ordering::Relaxed), 120);

    let allocators = svc.allocators();
    drop(c);
    drop(svc);
    for (i, a) in allocators.iter().enumerate() {
        assert!(a.debug_consistent(), "device {i} inconsistent");
        assert_eq!(
            a.counters().mallocs.load(Ordering::Relaxed),
            a.counters().frees.load(Ordering::Relaxed),
            "device {i} unbalanced after cached round trip"
        );
    }
}

/// The acceptance churn with mixed handles: 8 clients (half cached,
/// half ring-only) share one pool of live allocations. Cached blocks
/// freed through the wrong handle ride the delayed-free lists; the
/// global live set never holds a duplicate address; after the pool
/// drains and every handle drops, no lease is left registered and
/// every member's allocator counters balance.
#[test]
fn cached_churn_mixed_handles_conserves_live_set() {
    let policies = RoutePolicy::all();
    let mut checked_ops = 0u64;
    for seed in 0..chaos_seeds() {
        let route = policies[(seed as usize) % policies.len()];
        let svc = hetero_group(route);
        let pool: Mutex<(Vec<GlobalAddr>, HashSet<GlobalAddr>)> =
            Mutex::new((Vec::new(), HashSet::new()));
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let c = svc.client();
                if t % 2 == 0 {
                    c.set_caching(true);
                }
                let pool = &pool;
                s.spawn(move || {
                    let mut rng =
                        Rng::new(0xCAC4E + seed * 65_537 + t * 7919);
                    for _ in 0..200 {
                        if rng.chance(0.55) {
                            let size = rng.range(1, 8192) as u32;
                            let addr = c.alloc(size).unwrap_or_else(|e| {
                                panic!("{}: alloc({size}): {e}", route.id())
                            });
                            let mut g = pool.lock().unwrap();
                            assert!(
                                g.1.insert(addr),
                                "{}: duplicate live address {addr}",
                                route.id()
                            );
                            g.0.push(addr);
                        } else {
                            let victim_addr = {
                                let mut g = pool.lock().unwrap();
                                if g.0.is_empty() {
                                    continue;
                                }
                                let i = rng.below(g.0.len() as u64) as usize;
                                let a = g.0.swap_remove(i);
                                assert!(g.1.remove(&a));
                                a
                            };
                            // Any handle may free a cached block:
                            // non-owners ride the delayed-free list.
                            c.free(victim_addr).unwrap_or_else(|e| {
                                panic!(
                                    "{}: free({victim_addr}): {e}",
                                    route.id()
                                )
                            });
                        }
                    }
                    // Handle drop flushes the cache (surrendered
                    // leases with live blocks stay registered until
                    // their last block comes home).
                });
            }
        });

        // Drain the surviving pool through a fresh ring-only handle:
        // its frees of cached blocks are all cross-client, and the
        // last free of each surrendered lease returns the span.
        let drainer = svc.client();
        let leftovers = std::mem::take(&mut pool.lock().unwrap().0);
        for a in leftovers {
            drainer.free(a).unwrap_or_else(|e| {
                panic!("{}: drain free({a}): {e}", route.id())
            });
        }

        let stats = svc.stats();
        assert!(
            stats.cached_allocs.load(Ordering::Relaxed) > 0,
            "{}: the cached path never fired",
            route.id()
        );
        assert!(
            stats.delayed_frees.load(Ordering::Relaxed) > 0,
            "{}: no cross-client free ever rode the delayed list",
            route.id()
        );
        assert_eq!(svc.live_leases(), 0, "{}: leaked lease", route.id());
        assert_eq!(
            stats.lease_returns.load(Ordering::Relaxed),
            stats.lease_mints.load(Ordering::Relaxed),
            "{}: every minted span must come back",
            route.id()
        );
        let snap = svc.snapshot();
        assert_eq!(
            snap.allocs, snap.frees,
            "{}: seed {seed}: ring-level leak",
            route.id()
        );
        checked_ops += common::check_history(&svc.history());

        let allocators = svc.allocators();
        drop(drainer);
        drop(svc);
        for (i, a) in allocators.iter().enumerate() {
            assert!(
                a.debug_consistent(),
                "{}: device {i} inconsistent (seed {seed})",
                route.id()
            );
            assert_eq!(
                a.counters().mallocs.load(Ordering::Relaxed),
                a.counters().frees.load(Ordering::Relaxed),
                "{}: device {i} unbalanced (seed {seed})",
                route.id()
            );
        }
    }
    common::assert_chaos_coverage(checked_ops, chaos_seeds());
}

/// The tentpole race: 8 fully-cached clients churn cacheable classes
/// while the controller drains and retires a member mid-churn. Leased
/// spans on the victim are recalled and relocated through the drain;
/// cached names keep resolving through the lease registry at the new
/// home; nothing is lost and no client ever sees `DeviceRetired`.
#[test]
fn lease_recall_during_drain_preserves_live_set() {
    let policies = RoutePolicy::all();
    let mut checked_ops = 0u64;
    for seed in 0..chaos_seeds() {
        let route = policies[(seed as usize) % policies.len()];
        let svc = hetero_group(route);
        svc.set_forwarding_grace(Duration::from_secs(120));
        let victim = 1usize;
        let pool: Mutex<(Vec<GlobalAddr>, HashSet<GlobalAddr>)> =
            Mutex::new((Vec::new(), HashSet::new()));
        let drain_report = Mutex::new(None);
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let c = svc.client();
                c.set_caching(true);
                let pool = &pool;
                s.spawn(move || {
                    let mut rng =
                        Rng::new(0x5ECA11 + seed * 65_537 + t * 7919);
                    for _ in 0..200 {
                        if rng.chance(0.55) {
                            // Cacheable classes only: maximum lease
                            // traffic through the drain window.
                            let size = rng.range(1, 4096) as u32;
                            let addr = c.alloc(size).unwrap_or_else(|e| {
                                panic!("{}: alloc({size}): {e}", route.id())
                            });
                            let mut g = pool.lock().unwrap();
                            assert!(
                                g.1.insert(addr),
                                "{}: duplicate live address {addr}",
                                route.id()
                            );
                            g.0.push(addr);
                        } else {
                            let victim_addr = {
                                let mut g = pool.lock().unwrap();
                                if g.0.is_empty() {
                                    continue;
                                }
                                let i = rng.below(g.0.len() as u64) as usize;
                                let a = g.0.swap_remove(i);
                                assert!(g.1.remove(&a));
                                a
                            };
                            // Possibly a block of a recalled,
                            // relocated lease by now: the registry
                            // still resolves its origin-based name.
                            c.free(victim_addr).unwrap_or_else(|e| {
                                panic!(
                                    "{}: free({victim_addr}): {e}",
                                    route.id()
                                )
                            });
                        }
                    }
                });
            }
            let drain_report = &drain_report;
            let svc_ref = &svc;
            s.spawn(move || {
                // Fire mid-churn: wait for real cached traffic first.
                while svc_ref
                    .stats()
                    .cached_allocs
                    .load(Ordering::Relaxed)
                    < 150
                {
                    std::thread::sleep(Duration::from_micros(200));
                }
                let rep = svc_ref.drain_device(victim).expect("drain");
                quiesce_then_retire(svc_ref, victim);
                *drain_report.lock().unwrap() = Some(rep);
            });
        });
        let drain = drain_report.into_inner().unwrap().expect("controller");
        assert_eq!(
            drain.failed, 0,
            "{}: live blocks (leased spans included) must rehome",
            route.id()
        );
        assert_eq!(
            drain.unquiesced, 0,
            "{}: drain proceeded past in-flight ops",
            route.id()
        );
        for m in &drain.migrated {
            assert_eq!(m.from.device() as usize, victim);
            assert_ne!(m.to.device() as usize, victim);
        }

        // Drain the surviving pool: every cached name must still free
        // cleanly, recalled-and-relocated leases included.
        let drainer = svc.client();
        let leftovers = std::mem::take(&mut pool.lock().unwrap().0);
        for a in leftovers {
            drainer.free(a).unwrap_or_else(|e| {
                panic!("{}: drain free({a}): {e}", route.id())
            });
        }

        let stats = svc.stats();
        assert_eq!(
            stats.retired_ops.load(Ordering::Relaxed),
            0,
            "{}: a clean drain+quiesce+retire loses nothing",
            route.id()
        );
        assert_eq!(svc.live_leases(), 0, "{}: leaked lease", route.id());
        assert_eq!(
            stats.lease_returns.load(Ordering::Relaxed),
            stats.lease_mints.load(Ordering::Relaxed),
            "{}: every minted span must come back",
            route.id()
        );
        assert_eq!(svc.device_state(victim), DeviceState::Retired);
        let snap = svc.snapshot();
        assert_eq!(
            snap.allocs, snap.frees,
            "{}: seed {seed}: ring-level leak",
            route.id()
        );
        // Under OURO_LIN=1 the seed's full history — cached serves,
        // span carves, the recall-and-relocate — must linearize.
        checked_ops += common::check_history(&svc.history());

        let allocators = svc.allocators();
        drop(drainer);
        drop(svc);
        for (i, a) in allocators.iter().enumerate() {
            assert!(
                a.debug_consistent(),
                "{}: device {i} inconsistent (seed {seed})",
                route.id()
            );
            assert_eq!(
                a.counters().mallocs.load(Ordering::Relaxed),
                a.counters().frees.load(Ordering::Relaxed),
                "{}: device {i} unbalanced (seed {seed})",
                route.id()
            );
        }
    }
    common::assert_chaos_coverage(checked_ops, chaos_seeds());
}

/// Cross-client hand-off, deterministically: one cached owner carves
/// 96 blocks out of a single span; a ring-only helper frees them all
/// (every one a delayed free), a double free is rejected out of the
/// lease bitmap, and the owner re-serves a delayed block without a
/// second mint before the flush returns the span.
#[test]
fn cross_client_delayed_frees_drain_exactly_once() {
    let svc = hetero_group(RoutePolicy::RoundRobin);
    let owner = svc.client();
    owner.set_caching(true);
    let helper = svc.client();

    // 64-byte blocks: 128 per span, so 96 allocs stay inside one
    // lease and exactly one mint crosses the ring.
    let mut addrs = Vec::new();
    let mut uniq = HashSet::new();
    for _ in 0..96 {
        let a = owner.alloc(64).expect("cached alloc");
        assert!(uniq.insert(a), "duplicate cached address {a}");
        addrs.push(a);
    }
    let stats = svc.stats();
    assert_eq!(stats.lease_mints.load(Ordering::Relaxed), 1);

    for &a in &addrs {
        helper.free(a).expect("cross-client free");
    }
    assert_eq!(stats.cached_frees.load(Ordering::Relaxed), 96);
    assert_eq!(
        stats.delayed_frees.load(Ordering::Relaxed),
        96,
        "every non-owner free rides the delayed list"
    );

    // The bitmap catches the double free deterministically.
    assert!(matches!(
        helper.free(addrs[0]),
        Err(AllocError::InvalidFree(_))
    ));
    assert_eq!(stats.invalid_frees.load(Ordering::Relaxed), 1);

    // The owner's next serve drains the delayed list instead of
    // minting a second span.
    let b = owner.alloc(64).expect("re-serve from delayed list");
    assert_eq!(stats.lease_mints.load(Ordering::Relaxed), 1);
    owner.free(b).expect("owner free");

    owner.flush_cache();
    assert_eq!(svc.live_leases(), 0);
    assert_eq!(stats.lease_returns.load(Ordering::Relaxed), 1);
    let snap = svc.snapshot();
    assert_eq!(snap.allocs, snap.frees, "ring-level leak: {snap:?}");
}

/// Hard retire (no drain) with cached handles: blocks of leases homed
/// on the dead member answer `DeviceRetired` — the same deterministic
/// error as any other address there — while every other cached block
/// keeps freeing normally, and teardown stays clean under `OURO_SAN`.
#[test]
fn hard_retire_strands_leases_deterministically() {
    let svc = hetero_group(RoutePolicy::RoundRobin);
    let victim = 1usize;
    let c = svc.client();
    c.set_caching(true);

    // 4096-byte blocks: 2 per span, so 24 allocs spread 12 spans
    // round-robin across the 3 members.
    let mut addrs = Vec::new();
    for _ in 0..24 {
        addrs.push(c.alloc(4096).expect("cached alloc"));
    }
    svc.retire_device(victim);

    let (mut stranded, mut freed) = (0, 0);
    for a in addrs {
        if a.device() as usize == victim {
            assert!(
                matches!(c.free(a), Err(AllocError::DeviceRetired)),
                "free({a}) on the dead member must fail deterministically"
            );
            stranded += 1;
        } else {
            c.free(a).expect("free on a healthy member");
            freed += 1;
        }
    }
    assert!(stranded > 0, "round-robin never leased on the victim");
    assert!(freed > 0);

    // Flush tolerates the dead leases (their spans are stranded with
    // the member); healthy leases are returned.
    c.flush_cache();
    drop(c);
    drop(svc);
}

/// The readmit window guard: after a drain relocates a leased span
/// off the victim, the lease still *names* the victim's address
/// window (origin-based block names). Readmitting would re-mint that
/// window and alias the cached names, so it is refused until the
/// lease is returned — then it succeeds.
#[test]
fn readmit_refused_while_lease_names_window() {
    let svc = hetero_group(RoutePolicy::RoundRobin);
    svc.set_forwarding_grace(Duration::from_secs(120));
    let victim = 1usize;
    let c = svc.client();
    c.set_caching(true);

    // Lease spans round-robin until one lands on the victim; keep
    // every block live so the lease cannot finalize early.
    let mut pool = Vec::new();
    let mut on_victim = false;
    for _ in 0..64 {
        let a = c.alloc(4096).expect("cached alloc");
        on_victim |= a.device() as usize == victim;
        pool.push(a);
        if on_victim {
            break;
        }
    }
    assert!(on_victim, "round-robin never leased on the victim");

    let drain = svc.drain_device(victim).expect("drain");
    assert_eq!(drain.failed, 0, "leased span must relocate");
    quiesce_then_retire(&svc, victim);
    assert!(
        svc.stats().lease_recalls.load(Ordering::Relaxed) >= 1,
        "relocating a leased span is a recall"
    );

    // The lease survived the relocation and still names the victim's
    // origin window: readmission must refuse to re-mint it.
    assert!(matches!(
        svc.readmit_device(victim),
        Err(AllocError::ReadmitRefused)
    ));

    // Cached names keep resolving at the new home; the last free plus
    // the flush return the lease and clear the window.
    for a in pool {
        c.free(a).expect("free through the relocated lease");
    }
    c.flush_cache();
    assert_eq!(svc.live_leases(), 0);

    svc.readmit_device(victim).expect("readmit after lease return");
    assert_eq!(svc.device_state(victim), DeviceState::Healthy);
}

fn cached_churn(
    c: &FederationClient,
    rng: &mut Rng,
    pool: &mut Vec<GlobalAddr>,
    ops: usize,
) {
    for _ in 0..ops {
        if rng.chance(0.6) || pool.is_empty() {
            let size = rng.range(1, 4096) as u32;
            pool.push(c.alloc(size).expect("federated cached alloc"));
        } else {
            let i = rng.below(pool.len() as u64) as usize;
            let a = pool.swap_remove(i);
            c.free(a).expect("federated cached free");
        }
    }
}

/// Cached handles across a federation restart: the client frees its
/// cached blocks and flushes its per-group caches (the documented
/// pre-restart barrier — a lease is a live block, and cached names
/// do not survive a registry rebuild), the primary group restarts
/// from its snapshot, and the epoch-refreshed replacement client is
/// re-armed automatically and leases again.
#[test]
fn federation_cached_churn_survives_group_restart() {
    for seed in 0..chaos_seeds() {
        let cfg = HeapConfig { num_chunks: 256, ..HeapConfig::default() };
        let group = |variant| {
            AllocService::start_named_group(
                &[("t2000", variant), ("t2000", variant)],
                &cfg,
                BatchPolicy::default(),
                RoutePolicy::RoundRobin,
                Arc::new(Cuda::new()),
            )
        };
        let fed = FederationRouter::new(
            vec![group(Variant::Page), group(Variant::Chunk)],
            1,
        );
        let c = fed.client();
        c.set_caching(true);
        let g = c.primary();
        let mut rng = Rng::new(0xFED5 + seed * 97);
        let mut pool = Vec::new();

        cached_churn(&c, &mut rng, &mut pool, 200);

        // The pre-restart barrier: cached names die with the old
        // registry, so drain them and return every lease first.
        for a in pool.drain(..) {
            c.free(a).expect("pre-restart free");
        }
        c.flush_caches();
        assert_eq!(
            fed.with_group(g, |s| s.live_leases()).unwrap(),
            0,
            "seed {seed}: flush_caches must return every lease"
        );

        let (route, policy) = fed
            .with_group(g, |s| (s.route_policy(), s.batch_policy()))
            .expect("group slot filled");
        fed.restart_group(g, move |handoff| {
            AllocService::start_group_restored(
                handoff.rebuild_members(),
                policy,
                route,
                handoff,
            )
        })
        .expect("restart");

        // The replacement per-group client is minted lazily on the
        // next op and inherits the armed cache.
        cached_churn(&c, &mut rng, &mut pool, 150);
        assert!(
            fed.with_group(g, |s| {
                s.stats().cached_allocs.load(Ordering::Relaxed)
            })
            .unwrap()
                > 0,
            "seed {seed}: restarted group never served a cached alloc"
        );

        for a in pool.drain(..) {
            c.free(a).expect("post-restart free");
        }
        c.flush_caches();
        for gi in 0..2 {
            assert_eq!(
                fed.with_group(gi, |s| s.live_leases()).unwrap(),
                0,
                "seed {seed}: group {gi} leaked a lease"
            );
            // The restart handoff carries the recorder, so the history
            // spans both service generations — and must still
            // linearize as one.
            let lin = fed.with_group(gi, |s| s.history()).unwrap();
            common::check_history(&lin);
        }
    }
}
