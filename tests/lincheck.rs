//! Meta-tests for the analysis layer itself (ISSUE 10): the checkers
//! must not only pass on healthy executions — they must *detect
//! seeded faults*. A linearizability checker that never fires and a
//! deadlock detector that never trips are indistinguishable from
//! `true`; these tests pin the negative side.
//!
//! * an instrumented service's real mixed churn linearizes end to end
//!   (the positive control, independent of `OURO_LIN` in the
//!   environment);
//! * a seeded duplicate-live-address history is rejected, and the
//!   minimal window names the offending address;
//! * an inverted lock acquisition trips the cycle detector, and the
//!   panic carries *both* conflicting acquisition histories.

use std::collections::HashSet;
use std::panic::AssertUnwindSafe;
use std::sync::{Arc, Mutex};

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::check::history::{HistoryRecorder, OpKind, OpRecord};
use ouroboros_tpu::check::linearize;
use ouroboros_tpu::check::lockgraph::{self, classes, OrderedMutex};
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::router::RoutePolicy;
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::ouroboros::{
    build_allocator, GlobalAddr, HeapConfig, Variant,
};
use ouroboros_tpu::simt::{Device, DeviceProfile};
use ouroboros_tpu::util::rng::Rng;

/// A two-member instrumented group with an explicitly injected
/// recorder — armed regardless of `OURO_LIN`, so these tests behave
/// identically in the tier-1 and analysis CI legs.
fn instrumented_group() -> (AllocService, Arc<HistoryRecorder>) {
    let cfg = HeapConfig { num_chunks: 256, ..HeapConfig::default() };
    let lin = HistoryRecorder::new();
    let svc = AllocService::start_group_instrumented(
        vec![
            (
                Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
                build_allocator(Variant::Page, &cfg),
            ),
            (
                Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
                build_allocator(Variant::Chunk, &cfg),
            ),
        ],
        BatchPolicy::default(),
        RoutePolicy::RoundRobin,
        None,
        Some(lin.clone()),
    );
    (svc, lin)
}

/// Mixed ring + cached churn against `svc`; returns the surviving
/// live pool (empty if `drain` is set).
fn churn(svc: &AllocService, seed: u64, drain: bool) -> Vec<GlobalAddr> {
    let pool: Mutex<(Vec<GlobalAddr>, HashSet<GlobalAddr>)> =
        Mutex::new((Vec::new(), HashSet::new()));
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let c = svc.client();
            if t % 2 == 0 {
                c.set_caching(true);
            }
            let pool = &pool;
            s.spawn(move || {
                let mut rng = Rng::new(seed + t * 7919);
                for _ in 0..150 {
                    if rng.chance(0.6) {
                        let size = rng.range(1, 8192) as u32;
                        let a = c.alloc(size).expect("churn alloc");
                        let mut g = pool.lock().unwrap();
                        assert!(g.1.insert(a), "duplicate live address {a}");
                        g.0.push(a);
                    } else {
                        let victim = {
                            let mut g = pool.lock().unwrap();
                            if g.0.is_empty() {
                                continue;
                            }
                            let i = rng.below(g.0.len() as u64) as usize;
                            let a = g.0.swap_remove(i);
                            assert!(g.1.remove(&a));
                            a
                        };
                        c.free(victim).expect("churn free");
                    }
                }
            });
        }
    });
    let mut left = std::mem::take(&mut pool.lock().unwrap().0);
    if drain {
        let c = svc.client();
        for a in left.drain(..) {
            c.free(a).expect("drain free");
        }
    }
    left
}

/// Positive control: the real execution linearizes. Every partition of
/// a clean mixed churn — ring blocks per (device, class), lease spans
/// and cached blocks per lease id — passes the checker, and the
/// lock-order graph the run grew is acyclic.
#[test]
fn instrumented_churn_linearizes_end_to_end() {
    let (svc, lin) = instrumented_group();
    churn(&svc, 0x11C4EC4, true);
    let history = lin.harvest();
    assert!(
        history.len() >= 500,
        "churn must leave a real history, got {} ops",
        history.len()
    );
    let report = linearize::check(&history)
        .unwrap_or_else(|v| panic!("clean churn must linearize:\n{v}"));
    assert_eq!(report.ops, history.len());
    assert!(report.partitions >= 2, "two devices => at least 2 partitions");
    lockgraph::assert_acyclic();
    drop(svc);
}

/// Seeded fault #1: forge a second `Alloc` of an address that is still
/// live in its partition. The checker must reject the history, and the
/// minimal window it returns must name the duplicated address — that
/// window is the diagnosis an operator actually reads.
#[test]
fn seeded_duplicate_live_address_is_rejected_with_minimal_window() {
    let (svc, lin) = instrumented_group();
    let live = churn(&svc, 0xD011CA7E, false);
    assert!(!live.is_empty(), "need a live block to duplicate");
    let mut history = lin.harvest();

    // Find the ring-partition Alloc record of a still-live address (no
    // Free ever recorded for it) and replay it as a fresh allocation
    // "returning" the same address while the original is still live.
    let freed: HashSet<(u32, u32, u32)> = history
        .iter()
        .filter(|r| r.kind == OpKind::Free && r.lease_id == 0)
        .map(|r| (r.device, r.class, r.addr))
        .collect();
    let victim = history
        .iter()
        .find(|r| {
            r.kind == OpKind::Alloc
                && r.lease_id == 0
                && !freed.contains(&(r.device, r.class, r.addr))
        })
        .copied()
        .expect("an un-freed ring alloc exists");
    let end = history.iter().map(|r| r.res_ns).max().unwrap();
    history.push(OpRecord {
        inv_ns: end + 1,
        res_ns: end + 2,
        client: u64::MAX,
        ..victim
    });

    let v = linearize::check(&history)
        .expect_err("a duplicate live address must be rejected");
    assert_eq!(v.device, victim.device);
    assert_eq!(v.class, victim.class);
    assert!(!v.lease);
    assert!(
        v.window.iter().any(|r| r.addr == victim.addr),
        "the minimal window must name the duplicated address {:#x}: {v}",
        victim.addr
    );
    assert!(
        v.window.len() < history.len(),
        "the window is a minimized suffix, not the whole history"
    );
    drop(svc);
}

/// Seeded fault #2: after legally nesting batcher.fill -> ring.done
/// (the coordinator's real order), acquiring them inverted must trip
/// the detector *before* any deadlock can form, and the panic must
/// carry both acquisition histories — the previously recorded legal
/// edge and the offending acquisition site.
#[test]
fn inverted_lock_acquisition_trips_the_cycle_detector() {
    let fill = OrderedMutex::new(&classes::BATCHER_FILL, ());
    let done = OrderedMutex::new(&classes::RING_DONE, ());

    // The legal direction, recording the edge with its sample history.
    {
        let _outer = fill.lock().unwrap();
        let _inner = done.lock().unwrap();
    }
    assert!(
        lockgraph::observed_edges()
            .contains(&("batcher.fill", "ring.done")),
        "the legal nesting must be recorded as an edge"
    );
    lockgraph::assert_acyclic();

    // The inversion: rank discipline panics at acquisition.
    let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
        let _outer = done.lock().unwrap();
        let _inner = fill.lock().unwrap();
    }))
    .expect_err("inverted acquisition must panic");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .expect("panic payload is a message");
    assert!(msg.contains("lock-order cycle"), "{msg}");
    assert!(
        msg.contains("this acquisition"),
        "must carry the offending history: {msg}"
    );
    assert!(
        msg.contains("previously recorded batcher.fill -> ring.done"),
        "must carry the prior legal history: {msg}"
    );

    // The bad edge was never inserted: the graph is still a DAG and
    // later acquisitions on this thread are unaffected.
    lockgraph::assert_acyclic();
    let _again = fill.lock().unwrap();
}
