//! Full-stack driver integration: the benchmark loop with its data
//! phase executed through the AOT Pallas kernel via PJRT (the
//! examples/e2e_driver path, asserted).

use std::sync::Arc;

use ouroboros_tpu::backend::{Cuda, SyclOneapiNv};
use ouroboros_tpu::coordinator::driver::{run_driver, DataPhase, DriverConfig};
use ouroboros_tpu::ouroboros::{HeapConfig, Variant};
use ouroboros_tpu::runtime::Runtime;
use ouroboros_tpu::simt::{Device, DeviceProfile};

fn xla_cfg(variant: Variant, threads: u32, size: u32) -> DriverConfig {
    DriverConfig {
        variant,
        alloc_size: size,
        num_allocations: threads,
        iterations: 3,
        data_phase: DataPhase::Xla,
        heap: HeapConfig::default(),
        seed: 0xA0A,
    }
}

#[test]
fn xla_data_phase_verifies_on_page_and_chunk() {
    let rt = Runtime::load_default().expect("run `make artifacts`");
    for variant in [Variant::Page, Variant::VlChunk] {
        let dev = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
        let rep =
            run_driver(&dev, &xla_cfg(variant, 512, 1000), Some(&rt)).unwrap();
        assert!(rep.verify_ok(), "{}: XLA data phase failed", variant.id());
        // XLA wall time was actually measured.
        assert!(rep.iters.iter().all(|i| i.write_us > 0.0));
    }
}

#[test]
fn xla_data_phase_handles_non_batch_multiples() {
    // 700 threads != TOUCH_PAGES batch; the driver pads internally.
    let rt = Runtime::load_default().unwrap();
    let dev =
        Device::new(DeviceProfile::t2000(), Arc::new(SyclOneapiNv::new()));
    let rep =
        run_driver(&dev, &xla_cfg(Variant::Chunk, 700, 256), Some(&rt)).unwrap();
    assert!(rep.verify_ok());
}

#[test]
fn xla_data_phase_small_pages_respect_bounds() {
    // 16 B allocations: only 4 words writable per page; verification
    // must not touch neighbours.
    let rt = Runtime::load_default().unwrap();
    let dev = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    let rep =
        run_driver(&dev, &xla_cfg(Variant::Page, 512, 16), Some(&rt)).unwrap();
    assert!(rep.verify_ok());
}

#[test]
fn xla_required_but_missing_runtime_errors() {
    let dev = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    assert!(run_driver(&dev, &xla_cfg(Variant::Page, 64, 64), None).is_err());
}
