//! Self-healing device group under chaos — watchdog-driven retire,
//! incremental (paced) background rebalancing, and member readmit.
//!
//! `OURO_CHAOS_SEEDS` (default 2) controls how many seeds the
//! randomized tests run; CI sets 8 so nondeterministic interleavings
//! get real coverage on every push. Detection tests drive the
//! `HealthMonitor` with a `FakeClock`, so stall windows and probation
//! are deterministic regardless of CI load.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::driver::{
    run_group_trace, run_selfheal_trace,
};
use ouroboros_tpu::coordinator::router::{DeviceState, RoutePolicy};
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::coordinator::workload::churn_trace;
use ouroboros_tpu::coordinator::{
    DrainPacing, FakeClock, HealthEventKind, HealthPolicy, HealthVerdict,
    MigrationRecord, ServiceTraceReport, Ticket,
};
use ouroboros_tpu::ouroboros::{
    build_allocator, AllocError, GlobalAddr, HeapConfig, Variant,
};
use ouroboros_tpu::simt::{Device, DeviceProfile};
use ouroboros_tpu::util::rng::Rng;

fn chaos_seeds() -> u64 {
    std::env::var("OURO_CHAOS_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2)
        .max(1)
}

/// Homogeneous 3-member group with room to absorb a drained live set.
fn group3(route: RoutePolicy) -> AllocService {
    AllocService::start_named_group(
        &[("t2000", Variant::Page); 3],
        &HeapConfig { num_chunks: 256, ..HeapConfig::default() },
        BatchPolicy::default(),
        route,
        Arc::new(Cuda::new()),
    )
}

/// Fast, deterministic detection thresholds for fake-clock tests.
fn fast_policy() -> HealthPolicy {
    HealthPolicy {
        stall_window: Duration::from_millis(20),
        probation: Duration::from_millis(20),
        quiesce: Duration::from_millis(20),
        pace: DrainPacing {
            blocks_per_tick: 4,
            tick_pause: Duration::from_millis(1),
        },
        ..HealthPolicy::default()
    }
}

/// Watchdog auto-retire under an injected stall, across seeds and
/// routing policies: blocks land on the victim, its lane workers wedge
/// with frees parked in the ring, and the monitor — driven by a fake
/// clock, so the stall window and probation elapse deterministically —
/// trips, paced-drains the live set, and retires the member. Parked
/// frees are rescued to the migrated copies; nothing is lost.
#[test]
fn watchdog_auto_retires_stalled_member() {
    for seed in 0..chaos_seeds() {
        let route = RoutePolicy::all()[(seed as usize) % 4];
        let svc = group3(route);
        svc.set_forwarding_grace(Duration::from_secs(120));
        let victim = 1usize;
        let clock = Arc::new(FakeClock::new());
        let monitor = svc.monitor_with_clock(fast_policy(), clock.clone());
        let clients: Vec<_> = (0..3).map(|_| svc.client()).collect();

        // Land live blocks on the victim (clients[1] is pinned there
        // under ClientAffinity; the other policies rotate onto it).
        let mut on_victim: Vec<GlobalAddr> = Vec::new();
        let mut elsewhere: Vec<GlobalAddr> = Vec::new();
        let want = 6 + seed as usize;
        let mut attempts = 0;
        while on_victim.len() < want {
            let a = clients[victim].alloc(1000).unwrap();
            if a.device() as usize == victim {
                on_victim.push(a);
            } else {
                elsewhere.push(a);
            }
            attempts += 1;
            assert!(attempts < 10_000, "{}: victim never placed", route.id());
        }

        // Wedge the member, then park frees of its blocks in its lanes:
        // claimed ring descriptors with no dispatch progress — the
        // stall signature.
        svc.inject_stall(victim, true);
        let keep = on_victim.pop().unwrap();
        let parked: Vec<Ticket> = on_victim
            .iter()
            .map(|&a| clients[victim].submit_free(a).unwrap())
            .collect();

        // Baseline poll: establishes the progress heartbeat.
        monitor.poll_once(&svc);
        assert_eq!(monitor.verdict(victim), HealthVerdict::Ok);
        assert_eq!(svc.device_state(victim), DeviceState::Healthy);
        // Stall window elapses: tripped, but probation holds fire.
        clock.advance(Duration::from_millis(25));
        monitor.poll_once(&svc);
        assert_eq!(monitor.verdict(victim), HealthVerdict::Stalled);
        assert_eq!(
            svc.device_state(victim),
            DeviceState::Healthy,
            "{}: probation must hold fire",
            route.id()
        );
        // Probation elapses: the watchdog drains and retires — no
        // manual retire_device call anywhere in this test.
        clock.advance(Duration::from_millis(25));
        monitor.poll_once(&svc);
        assert_eq!(
            svc.device_state(victim),
            DeviceState::Retired,
            "{} seed {seed}",
            route.id()
        );

        let events = monitor.events();
        assert!(
            matches!(
                events.first(),
                Some(e) if e.device == victim
                    && e.kind
                        == HealthEventKind::Tripped(HealthVerdict::Stalled)
            ),
            "{}: {events:?}",
            route.id()
        );
        let (migrated, failed, unquiesced) = events
            .iter()
            .find_map(|e| match e.kind {
                HealthEventKind::Drained { migrated, failed, unquiesced, .. } => {
                    Some((migrated, failed, unquiesced))
                }
                _ => None,
            })
            .expect("watchdog must record its drain");
        assert_eq!(failed, 0, "{}: live blocks not rehomed", route.id());
        assert_eq!(unquiesced, 0, "{}: no allocs were in flight", route.id());
        assert_eq!(
            migrated,
            want as u64,
            "{}: whole live set must migrate",
            route.id()
        );
        assert!(events.iter().any(|e| matches!(
            e.kind,
            HealthEventKind::Retired { .. }
        )));

        // Parked frees were rescued to the migrated copies — completed
        // Ok, not DeviceRetired, and each block freed exactly once.
        for t in parked {
            clients[victim]
                .wait(t)
                .expect("completion, not a hang")
                .into_free()
                .unwrap_or_else(|e| {
                    panic!("{}: parked free lost: {e}", route.id())
                });
        }
        // The unfreed block's stale name forwards at submit.
        clients[0].free(keep).expect("stale free forwards");
        for a in elsewhere {
            clients[0].free(a).unwrap();
        }
        assert_eq!(
            svc.stats().forwarded_frees.load(Ordering::Relaxed),
            migrated,
            "{}: every migrated block freed through exactly one forward",
            route.id()
        );

        let allocators = svc.allocators();
        drop(svc);
        for (i, a) in allocators.iter().enumerate() {
            assert!(a.debug_consistent(), "device {i}, seed {seed}");
            assert_eq!(
                a.counters().mallocs.load(Ordering::Relaxed),
                a.counters().frees.load(Ordering::Relaxed),
                "device {i} unbalanced, seed {seed}"
            );
        }
    }
}

/// Regression: a *served* ticket a slow client has not reaped yet must
/// never read as a stall — the watchdog's signal is unserved work
/// (claimed minus completed), so a healthy member with completed-but-
/// unreaped descriptors stays healthy however long the client dawdles.
#[test]
fn completed_but_unreaped_tickets_never_trip_the_watchdog() {
    let svc = group3(RoutePolicy::RoundRobin);
    let clock = Arc::new(FakeClock::new());
    let monitor = svc.monitor_with_clock(fast_policy(), clock.clone());
    let c = svc.client();
    let t = c.submit_alloc(1000).unwrap();
    // Let the op complete (dispatch publishes a batch), then just...
    // don't reap it.
    let dev = t.device();
    let mut spins = 0;
    while svc.snapshot().devices[dev].batches == 0 {
        std::thread::sleep(Duration::from_micros(100));
        spins += 1;
        assert!(spins < 100_000, "op never dispatched");
    }
    monitor.poll_once(&svc);
    clock.advance(Duration::from_secs(3600));
    monitor.poll_once(&svc);
    clock.advance(Duration::from_secs(3600));
    monitor.poll_once(&svc);
    assert_eq!(monitor.verdict(dev), HealthVerdict::Ok);
    assert_eq!(
        svc.device_state(dev),
        DeviceState::Healthy,
        "a slow reaper must never get its device retired"
    );
    // The dawdling client finally reaps; everything still works.
    let a = c.wait(t).unwrap().into_alloc().unwrap();
    c.free(a).unwrap();
}

/// Error-storm detection: a member whose heap is exhausted keeps
/// serving (and failing) allocs — dispatch progress never stops, so
/// stall detection stays quiet, but the error-rate heartbeat trips,
/// survives probation (sticky between observation windows), and the
/// watchdog drains its whole live set onto the healthy member.
#[test]
fn watchdog_retires_error_storm_member() {
    let tiny = HeapConfig { num_chunks: 4, ..HeapConfig::default() };
    let big = HeapConfig { num_chunks: 512, ..HeapConfig::default() };
    let svc = AllocService::start_group(
        vec![
            (
                Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
                build_allocator(Variant::Page, &tiny),
            ),
            (
                Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
                build_allocator(Variant::Page, &big),
            ),
        ],
        BatchPolicy::default(),
        RoutePolicy::ClientAffinity,
    );
    svc.set_forwarding_grace(Duration::from_secs(120));
    let clock = Arc::new(FakeClock::new());
    let policy = HealthPolicy {
        // Stall detection out of the way: this test is about errors.
        stall_window: Duration::from_secs(3600),
        error_rate: 0.5,
        min_ops: 32,
        probation: Duration::from_millis(20),
        quiesce: Duration::from_millis(20),
        ..HealthPolicy::default()
    };
    let monitor = svc.monitor_with_clock(policy, clock.clone());
    let c = svc.client(); // affinity 0 = the tiny member

    // Fill the tiny heap to its OOM wall.
    let mut live: Vec<GlobalAddr> = Vec::new();
    loop {
        match c.alloc(1000) {
            Ok(a) => {
                assert_eq!(a.device(), 0, "affinity must pin the tiny member");
                live.push(a);
            }
            Err(AllocError::OutOfMemory) => break,
            Err(e) => panic!("unexpected fill error: {e}"),
        }
    }
    assert!(!live.is_empty());
    // Absorb the fill into the first observation window (one OOM error
    // across the whole fill: healthy rate).
    monitor.poll_once(&svc);
    assert_eq!(monitor.verdict(0), HealthVerdict::Ok);

    // Error storm: every alloc now fails on the pinned member.
    for _ in 0..64 {
        let _ = c.alloc(1000);
    }
    monitor.poll_once(&svc);
    assert_eq!(monitor.verdict(0), HealthVerdict::ErrorStorm);
    assert_eq!(
        svc.device_state(0),
        DeviceState::Healthy,
        "probation must hold fire"
    );
    clock.advance(Duration::from_millis(25));
    // No fresh window since — the storm verdict must stick through
    // probation rather than hide behind an incomplete window.
    monitor.poll_once(&svc);
    assert_eq!(svc.device_state(0), DeviceState::Retired);
    let drained = monitor
        .events()
        .iter()
        .find_map(|e| match e.kind {
            HealthEventKind::Drained { migrated, failed, .. } => {
                Some((migrated, failed))
            }
            _ => None,
        })
        .expect("drain event");
    assert_eq!(drained.1, 0, "big member must absorb the live set");
    assert_eq!(drained.0, live.len() as u64);

    // Every stale name forwards onto the big member; nothing lost.
    for a in live {
        c.free(a).unwrap();
    }
    let snap = svc.snapshot();
    let allocators = svc.allocators();
    drop(svc);
    for (i, a) in allocators.iter().enumerate() {
        assert!(a.debug_consistent(), "device {i}");
        // `mallocs` counts *requests* (the OOM storm included), so the
        // conservation law here is mallocs == frees + failed requests.
        assert_eq!(
            a.counters().mallocs.load(Ordering::Relaxed),
            a.counters().frees.load(Ordering::Relaxed)
                + snap.devices[i].alloc_errors,
            "device {i}: every successful alloc must be freed exactly once"
        );
    }
}

/// Paced drain: bounded work per tick, persistent cursor across
/// interruption, live traffic interleaving mid-sweep, and the full
/// live set conserved across resume.
#[test]
fn paced_drain_resumes_from_cursor_and_conserves_live_set() {
    for seed in 0..chaos_seeds() {
        let svc = group3(RoutePolicy::RoundRobin);
        svc.set_forwarding_grace(Duration::from_secs(120));
        let victim = 1usize;
        let c = svc.client();
        let mut rng = Rng::new(0x9A11 + seed * 65_537);
        let pool: Vec<GlobalAddr> = (0..120)
            .map(|_| c.alloc(rng.range(16, 4096) as u32).unwrap())
            .collect();
        let on_victim =
            pool.iter().filter(|a| a.device() as usize == victim).count();
        assert!(on_victim > 0, "seed {seed}: round-robin skipped the victim");

        let unquiesced =
            svc.begin_drain(victim, Duration::from_millis(200)).unwrap();
        assert_eq!(unquiesced, 0, "seed {seed}");
        // First tick: at most 3 live blocks handled.
        let t1 = svc.drain_tick(victim, 3).unwrap();
        assert!(
            t1.migrated.len() as u64 + t1.skipped_freed + t1.failed <= 3,
            "seed {seed}: tick exceeded its budget: {t1:?}"
        );
        // Live traffic interleaves mid-drain; the draining member is
        // never placed.
        let extra = c.alloc(512).unwrap();
        assert_ne!(extra.device() as usize, victim, "seed {seed}");
        // "Interruption" is just not ticking; the cursor is persistent,
        // so resuming ticks continues exactly where the sweep stopped.
        let mut migrated: Vec<MigrationRecord> = t1.migrated.clone();
        let mut failed = t1.failed;
        let mut rounds = 0;
        if !t1.complete {
            loop {
                let t = svc.drain_tick(victim, 3).unwrap();
                migrated.extend(t.migrated);
                failed += t.failed;
                if t.complete {
                    break;
                }
                rounds += 1;
                assert!(rounds < 10_000, "seed {seed}: drain never completed");
            }
        }
        assert_eq!(failed, 0, "seed {seed}");
        assert_eq!(
            migrated.len(),
            on_victim,
            "seed {seed}: resumed sweep must cover the whole live set"
        );
        // No block re-homed twice, every source from the victim.
        let mut froms: Vec<GlobalAddr> = migrated.iter().map(|m| m.from).collect();
        froms.sort_unstable();
        froms.dedup();
        assert_eq!(froms.len(), migrated.len(), "seed {seed}: double-migrated");
        for m in &migrated {
            assert_eq!(m.from.device() as usize, victim);
            assert_ne!(m.to.device() as usize, victim);
        }
        // A completed sweep's further ticks are empty no-ops...
        let done = svc.drain_tick(victim, 8).unwrap();
        assert!(done.complete && done.migrated.is_empty(), "seed {seed}");
        // ...ticking a healthy member is refused...
        assert!(matches!(
            svc.drain_tick(0, 8),
            Err(AllocError::DeviceRetired)
        ));
        // ...and so is ticking after the retire.
        svc.wait_lanes_quiet(victim, Duration::from_millis(250));
        svc.retire_device(victim);
        assert!(matches!(
            svc.drain_tick(victim, 8),
            Err(AllocError::DeviceRetired)
        ));

        c.free(extra).unwrap();
        for a in pool {
            c.free(a).unwrap();
        }
        let allocators = svc.allocators();
        drop(svc);
        for (i, a) in allocators.iter().enumerate() {
            assert!(a.debug_consistent(), "device {i}, seed {seed}");
            assert_eq!(
                a.counters().mallocs.load(Ordering::Relaxed),
                a.counters().frees.load(Ordering::Relaxed),
                "device {i} unbalanced, seed {seed}"
            );
        }
    }
}

/// Readmit-then-churn under all four route policies: drain + retire a
/// member, flush every stale name through the forwarding table, take
/// the member back, and drive fresh churn — the readmitted member must
/// serve allocations again under every policy, with the group's books
/// balanced at the end.
#[test]
fn readmit_then_churn_under_all_policies() {
    for seed in 0..chaos_seeds() {
        for route in RoutePolicy::all() {
            let svc = group3(route);
            svc.set_forwarding_grace(Duration::from_secs(120));
            let victim = 1usize;
            let c = svc.client();
            let pool: Vec<GlobalAddr> = (0..60)
                .map(|i| c.alloc(256 + (i % 512) as u32).unwrap())
                .collect();
            let rep = svc.drain_device(victim).unwrap();
            assert_eq!(rep.failed, 0, "{}", route.id());
            svc.wait_lanes_quiet(victim, Duration::from_millis(250));
            svc.retire_device(victim);
            // Flush stale names *before* the readmit re-mints the
            // victim's address window.
            for a in pool {
                c.free(a).unwrap();
            }
            let r = svc.readmit_device(victim).unwrap_or_else(|e| {
                panic!("{} seed {seed}: readmit: {e}", route.id())
            });
            assert_eq!(r.device, victim);
            assert!(r.lanes > 0);
            assert_eq!(svc.device_state(victim), DeviceState::Healthy);
            assert_eq!(svc.healthy_devices(), 3, "{}", route.id());

            let before = svc.snapshot().devices[victim].allocs;
            let trace = churn_trace(0x4EAD + seed * 7919, 32, 200, 4096);
            let reps = run_group_trace(&svc, 4, &trace, 8)
                .unwrap_or_else(|e| {
                    panic!("{} seed {seed}: post-readmit churn: {e}", route.id())
                });
            let agg = ServiceTraceReport::merged(&reps);
            assert_eq!(agg.alloc_failures, 0, "{}", route.id());
            let snap = svc.snapshot();
            assert!(
                snap.devices[victim].allocs > before,
                "{} seed {seed}: readmitted member served nothing: {snap:?}",
                route.id()
            );
            assert_eq!(snap.devices[victim].state, "healthy");
            assert_eq!(snap.readmits, 1, "{}", route.id());

            let allocators = svc.allocators();
            drop(svc);
            for (i, a) in allocators.iter().enumerate() {
                assert!(
                    a.debug_consistent(),
                    "{}: device {i}, seed {seed}",
                    route.id()
                );
                assert_eq!(
                    a.counters().mallocs.load(Ordering::Relaxed),
                    a.counters().frees.load(Ordering::Relaxed),
                    "{}: device {i} unbalanced, seed {seed}",
                    route.id()
                );
            }
        }
    }
}

/// Readmit rejections: healthy and draining members refuse, a hard
/// retire with stranded blocks refuses (and rolls back to Retired),
/// a clean retire readmits exactly once.
#[test]
fn readmit_rejections_double_and_while_draining() {
    let svc = group3(RoutePolicy::RoundRobin);
    svc.set_forwarding_grace(Duration::from_secs(120));
    // Healthy member: refused.
    assert_eq!(
        svc.readmit_device(1).unwrap_err(),
        AllocError::ReadmitRefused
    );
    let c = svc.client();
    // A serial round-robin client lands 4 of 12 blocks on each member.
    let pool: Vec<GlobalAddr> =
        (0..12).map(|_| c.alloc(1000).unwrap()).collect();
    assert!(pool.iter().any(|a| a.device() == 1));
    // Draining member: refused, and the drain state is untouched.
    svc.begin_drain(1, Duration::from_millis(100)).unwrap();
    assert_eq!(
        svc.readmit_device(1).unwrap_err(),
        AllocError::ReadmitRefused
    );
    assert_eq!(svc.device_state(1), DeviceState::Draining);
    // Hard retire with the live set stranded: the emptiness assert
    // refuses and rolls back to Retired (the strands stay addressable
    // for forensics, never re-minted).
    svc.retire_device(1);
    assert_eq!(
        svc.readmit_device(1).unwrap_err(),
        AllocError::ReadmitRefused
    );
    assert_eq!(svc.device_state(1), DeviceState::Retired);

    // A clean drain + retire on another member readmits fine — once.
    svc.drain_device(2).expect("drain");
    svc.wait_lanes_quiet(2, Duration::from_millis(250));
    svc.retire_device(2);
    svc.readmit_device(2).expect("clean readmit");
    assert_eq!(svc.device_state(2), DeviceState::Healthy);
    assert_eq!(
        svc.readmit_device(2).unwrap_err(),
        AllocError::ReadmitRefused,
        "double readmit"
    );
    assert_eq!(svc.healthy_devices(), 2);

    // Stranded blocks are deterministically dead; everything else
    // (incl. device 2's migrated set) frees cleanly.
    for a in pool {
        match a.device() {
            1 => assert_eq!(c.free(a), Err(AllocError::DeviceRetired)),
            _ => c.free(a).unwrap(),
        }
    }
}

/// The acceptance scenario, end to end: a member stalls mid-churn and
/// the service — with **no manual `retire_device` call** — detects,
/// paced-drains, retires, and later readmits it, finishing with zero
/// lost/double-freed blocks and the readmitted member serving fresh
/// allocations.
#[test]
fn e2e_stall_detect_paced_drain_retire_readmit() {
    for seed in 0..chaos_seeds() {
        let svc = group3(RoutePolicy::RoundRobin);
        svc.set_forwarding_grace(Duration::from_secs(120));
        let victim = 1usize;
        let policy = HealthPolicy {
            stall_window: Duration::from_millis(10),
            probation: Duration::from_millis(10),
            tick: Duration::from_millis(2),
            quiesce: Duration::from_millis(100),
            pace: DrainPacing {
                blocks_per_tick: 8,
                tick_pause: Duration::from_micros(500),
            },
            ..HealthPolicy::default()
        };
        let trace = churn_trace(0x5E1F + seed * 7919, 48, 300, 4096);
        let rep = run_selfheal_trace(&svc, 6, &trace, 8, victim, 200, policy)
            .unwrap_or_else(|e| panic!("seed {seed}: selfheal trace: {e}"));

        let victim_events: Vec<&HealthEventKind> = rep
            .events
            .iter()
            .filter(|e| e.device == victim)
            .map(|e| &e.kind)
            .collect();
        assert!(
            victim_events.iter().any(|k| matches!(
                k,
                HealthEventKind::Tripped(HealthVerdict::Stalled)
            )),
            "seed {seed}: watchdog never tripped: {:?}",
            rep.events
        );
        assert!(
            victim_events
                .iter()
                .any(|k| matches!(k, HealthEventKind::Drained { failed: 0, .. })),
            "seed {seed}: paced drain must rehome everything: {:?}",
            rep.events
        );
        assert!(victim_events
            .iter()
            .any(|k| matches!(k, HealthEventKind::Retired { .. })));
        assert!(rep.recovery_us > 0.0, "seed {seed}");
        assert_eq!(rep.readmit.device, victim);
        assert!(
            rep.readmitted_allocs > 0,
            "seed {seed}: readmitted member served no fresh allocations"
        );
        assert_eq!(svc.device_state(victim), DeviceState::Healthy);
        let post = ServiceTraceReport::merged(&rep.post_reports);
        assert_eq!(
            post.alloc_failures, 0,
            "seed {seed}: healed group must serve cleanly"
        );
        assert_eq!(post.retired_ops, 0, "seed {seed}");

        // Zero lost / double-freed blocks, end to end.
        let allocators = svc.allocators();
        drop(svc);
        for (i, a) in allocators.iter().enumerate() {
            assert!(a.debug_consistent(), "device {i}, seed {seed}");
            assert_eq!(
                a.counters().mallocs.load(Ordering::Relaxed),
                a.counters().frees.load(Ordering::Relaxed),
                "device {i} unbalanced, seed {seed}"
            );
        }
    }
}
