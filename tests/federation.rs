//! Cross-group federation chaos suite — whole-group exhaustion
//! spillover, tag-routed cross-group frees, durable restart
//! (kill + restore-from-snapshot mid-churn), automatic failback, and
//! the client-side transient-failure retry.
//!
//! `OURO_CHAOS_SEEDS` (default 2) controls how many RNG seeds the
//! randomized tests loop; CI's analysis job runs this file at 8 seeds
//! under `OURO_SAN=1`, so every federated alloc/free/migration is
//! double-entry bookkept by the shadow heap across the restarts, and
//! under `OURO_LIN=1` so every group's recorded op history linearizes
//! (see `common::check_history`).

mod common;

use std::collections::HashSet;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::driver::{
    failover_quiesce_timeout, run_federation_trace, ServiceTraceReport,
};
use ouroboros_tpu::coordinator::federation::{
    FederationEventKind, FederationRouter, GroupPressure,
};
use ouroboros_tpu::coordinator::rebalance::{Clock, FakeClock};
use ouroboros_tpu::coordinator::router::RoutePolicy;
use ouroboros_tpu::coordinator::service::{
    AllocService, Handoff, RetryPolicy,
};
use ouroboros_tpu::coordinator::snapshot::ServiceSnapshot;
use ouroboros_tpu::coordinator::workload::churn_trace;
use ouroboros_tpu::ouroboros::params::CHUNK_SIZE;
use ouroboros_tpu::ouroboros::{AllocError, GlobalAddr, HeapConfig, Variant};
use ouroboros_tpu::util::rng::Rng;

fn chaos_seeds() -> u64 {
    std::env::var("OURO_CHAOS_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2)
        .max(1)
}

/// A two-member homogeneous group over `cfg`-sized heaps.
fn group_with(cfg: &HeapConfig, route: RoutePolicy) -> AllocService {
    AllocService::start_named_group(
        &[("t2000", Variant::Page), ("t2000", Variant::Page)],
        cfg,
        BatchPolicy::default(),
        route,
        Arc::new(Cuda::new()),
    )
}

fn small_group(route: RoutePolicy) -> AllocService {
    group_with(&HeapConfig::test_small(), route)
}

/// The canonical restart rebuild: same heaps, same policies.
fn restart_in_place(
    fed: &FederationRouter,
    g: usize,
) -> Result<(), AllocError> {
    let (route, policy) = fed
        .with_group(g, |s| (s.route_policy(), s.batch_policy()))
        .expect("group slot filled");
    fed.restart_group(g, move |handoff| {
        AllocService::start_group_restored(
            handoff.rebuild_members(),
            policy,
            route,
            handoff,
        )
    })
}

// ---------------------------------------------------------------------------
// Whole-group exhaustion: spillover and capacity failback
// ---------------------------------------------------------------------------

/// Fill a tiny CapacityAware group chunk by chunk until placement
/// spills to the standby group; then free the primary back down and
/// prove `poll_health` fails placements back — with the readmit
/// hysteresis, not the shed threshold, deciding recovery.
#[test]
fn capacity_exhaustion_spills_then_fails_back() {
    // 4 chunks per member: occupancy quantum 0.25, so shed_above=0.85
    // means "completely full" and readmit_below=0.70 means "at most
    // half full".
    let tiny = HeapConfig { num_chunks: 4, ..HeapConfig::test_small() };
    let fed = FederationRouter::with_clock(
        vec![
            group_with(&tiny, RoutePolicy::CapacityAware),
            small_group(RoutePolicy::RoundRobin),
        ],
        1,
        Arc::new(FakeClock::new()),
    );
    let c = fed.client();
    assert_eq!(c.primary(), 0);

    // Chunk-sized allocs: each one occupies a whole chunk, so the
    // primary's 2x4 chunks are gone after at most 8 placements.
    let mut primary_blocks = Vec::new();
    let mut spilled_addr = None;
    for _ in 0..32 {
        let a = c.alloc(CHUNK_SIZE).expect("federation has standby space");
        if a.group() == 0 {
            primary_blocks.push(a);
        } else {
            spilled_addr = Some(a);
            break;
        }
    }
    let spilled_addr = spilled_addr.expect("primary never spilled");
    assert!(fed.is_spilled(0), "spill must latch the primary");
    let s = fed.stats();
    assert!(s.spilled_allocs >= 1, "{s:?}");
    assert_eq!(s.spill_events, 1, "{s:?}");
    assert_eq!(
        fed.group_pressure(0),
        GroupPressure::Saturated,
        "a full CapacityAware group reads as saturated"
    );

    // Still latched while the primary sits above the readmit band.
    assert_eq!(fed.poll_health(), 0, "no failback while saturated");

    // Free the primary's blocks: occupancy drops to 0 < readmit_below.
    for a in primary_blocks {
        c.free(a).expect("primary-group free");
    }
    assert_eq!(fed.poll_health(), 1, "recovery must be observed");
    assert!(!fed.is_spilled(0));
    assert_eq!(fed.stats().failbacks, 1);

    // Placement fails back; the spilled block still frees by tag.
    let back = c.alloc(CHUNK_SIZE).expect("post-failback alloc");
    assert_eq!(back.group(), 0, "placement must return to the primary");
    c.free(back).unwrap();
    c.free(spilled_addr).expect("cross-group free of the spilled block");
    let kinds: Vec<FederationEventKind> =
        fed.events().iter().map(|e| e.kind).collect();
    assert_eq!(
        kinds,
        vec![FederationEventKind::Spilled, FederationEventKind::Recovered]
    );
    fed.shutdown();
}

/// The background watchdog drives the same failback with no manual
/// `poll_health` call: retire one member of a quorum-2 group, watch the
/// spill latch, repair the member, and wait (bounded) for the watchdog
/// to un-latch it.
#[test]
fn watchdog_fails_back_without_operator_calls() {
    let fed = FederationRouter::new(
        vec![
            small_group(RoutePolicy::RoundRobin),
            small_group(RoutePolicy::RoundRobin),
        ],
        2,
    );
    fed.spawn_watchdog(Duration::from_millis(1));
    let c = fed.client();
    assert_eq!(c.primary(), 0);

    // Nothing lives on the member, so hard-retire is clean.
    fed.with_group(0, |svc| {
        svc.retire_device(0);
    })
    .unwrap();
    // healthy(1) < quorum(2): the next placement spills and latches.
    let a = c.alloc(1024).unwrap();
    assert_eq!(a.group(), 1);
    assert!(fed.is_spilled(0));

    // Repair; the watchdog must notice on its own.
    fed.with_group(0, |svc| svc.readmit_device(0).map(|_| ()))
        .unwrap()
        .expect("readmit");
    let deadline = Instant::now() + Duration::from_secs(5);
    while fed.is_spilled(0) {
        assert!(
            Instant::now() < deadline,
            "watchdog never failed the group back"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(fed.stats().failbacks >= 1);
    let b = c.alloc(1024).unwrap();
    assert_eq!(b.group(), 0);
    c.free(a).unwrap();
    c.free(b).unwrap();
    fed.shutdown();
}

// ---------------------------------------------------------------------------
// The acceptance chaos: spillover churn + mid-churn kill/restore
// ---------------------------------------------------------------------------

/// Seed-looped shared-pool churn over a two-group federation while the
/// controller (a) drains + retires a member of group 0 so the quorum-2
/// federation sheds the whole group mid-churn, and (b) kills and
/// restores group 0's service from its durable handoff while traffic
/// keeps flowing. Invariants, per seed:
///
/// * the global live set never holds a duplicate federated address;
/// * every free succeeds — cross-group by tag, stale names through the
///   (restored) forwarding table, across the restart included;
/// * the restart is invisible to clients: zero `DeviceRetired`-failed
///   federated ops from it (the drain+retire contributes none either —
///   drained blocks forward), zero lost blocks in the closing sweep.
///
/// Run under `OURO_SAN=1` (CI's analysis job does) to double-entry
/// bookkeep every address across the migration and the restart.
#[test]
fn spillover_churn_with_mid_churn_restart_conserves_blocks() {
    let mut checked_ops = 0u64;
    for seed in 0..chaos_seeds() {
        let fed = FederationRouter::new(
            vec![
                small_group(RoutePolicy::RoundRobin),
                small_group(RoutePolicy::RoundRobin),
            ],
            2,
        );
        fed.with_group(0, |s| s.set_forwarding_grace(Duration::from_secs(120)))
            .unwrap();
        let pool: Mutex<(Vec<GlobalAddr>, HashSet<GlobalAddr>)> =
            Mutex::new((Vec::new(), HashSet::new()));
        let controller_err: Mutex<Option<String>> = Mutex::new(None);
        std::thread::scope(|s| {
            for t in 0..6u64 {
                let c = fed.client();
                let pool = &pool;
                s.spawn(move || {
                    let mut rng = Rng::new(0xFED0 + seed * 65_537 + t * 7919);
                    for _ in 0..200 {
                        if rng.chance(0.55) {
                            let size = rng.range(1, 8192) as u32;
                            let addr = c
                                .alloc(size)
                                .unwrap_or_else(|e| panic!("alloc({size}): {e}"));
                            let mut g = pool.lock().unwrap();
                            assert!(
                                g.1.insert(addr),
                                "duplicate federated address {addr}"
                            );
                            g.0.push(addr);
                        } else {
                            let victim = {
                                let mut g = pool.lock().unwrap();
                                if g.0.is_empty() {
                                    continue;
                                }
                                let i = rng.below(g.0.len() as u64) as usize;
                                let a = g.0.swap_remove(i);
                                assert!(g.1.remove(&a));
                                a
                            };
                            c.free(victim)
                                .unwrap_or_else(|e| panic!("free({victim}): {e}"));
                        }
                    }
                });
            }
            let fed_ref = &fed;
            let controller_err = &controller_err;
            s.spawn(move || {
                let run = || -> Result<(), String> {
                    let wait_ops = |at: u64| {
                        loop {
                            let st = fed_ref.stats();
                            if st.allocs + st.frees >= at {
                                break;
                            }
                            std::thread::sleep(Duration::from_micros(200));
                        }
                    };
                    // Phase 1, mid-churn: drain + retire one member of
                    // group 0. healthy(1) < quorum(2) ⇒ the federation
                    // sheds the whole group; its blocks keep freeing by
                    // tag (live ones in place, migrated ones forwarded).
                    wait_ops(150);
                    fed_ref
                        .with_group(0, |svc| {
                            svc.drain_device(0).map_err(|e| e.to_string())?;
                            svc.wait_lanes_quiet(0, failover_quiesce_timeout());
                            svc.retire_device(0);
                            Ok::<(), String>(())
                        })
                        .expect("group 0 live")?;
                    fed_ref.poll_health();
                    // Phase 2, deeper in: kill group 0's service and
                    // restore it from the handoff — same heaps, same
                    // forwarding promises; the retired member comes
                    // back healthy (its live set fully migrated), so
                    // the restart doubles as the repair.
                    wait_ops(400);
                    restart_in_place(fed_ref, 0).map_err(|e| e.to_string())?;
                    fed_ref.poll_health();
                    Ok(())
                };
                *controller_err.lock().unwrap() = run().err();
            });
        });
        assert_eq!(*controller_err.lock().unwrap(), None, "seed {seed}");
        let s = fed.stats();
        assert_eq!(s.restarts, 1, "seed {seed}: {s:?}");
        assert!(
            s.spill_events >= 1,
            "seed {seed}: losing quorum must shed the group: {s:?}"
        );
        assert!(
            fed.events()
                .iter()
                .any(|e| e.kind == FederationEventKind::Restarted),
            "seed {seed}"
        );
        // After the restart repaired the group and poll_health ran,
        // placements reach both groups again.
        assert!(!fed.is_spilled(0), "seed {seed}");
        assert!(!fed.is_spilled(1), "seed {seed}");

        // Closing sweep: every surviving block must free cleanly —
        // zero lost blocks across the shed, the churn and the restart.
        let sweeper = fed.client();
        let leftovers = std::mem::take(&mut pool.lock().unwrap().0);
        for a in leftovers {
            sweeper
                .free(a)
                .unwrap_or_else(|e| panic!("seed {seed}: sweep free({a}): {e}"));
        }
        let s = fed.stats();
        assert_eq!(s.allocs, s.frees, "seed {seed}: {s:?}");
        // Under OURO_LIN=1 each group's history — the restart-spanning
        // one included, since the handoff carries the recorder — must
        // linearize.
        for gi in 0..2 {
            let lin = fed.with_group(gi, |svc| svc.history()).unwrap();
            checked_ops += common::check_history(&lin);
        }
        fed.shutdown();
    }
    common::assert_chaos_coverage(checked_ops, chaos_seeds());
}

/// The driver-level acceptance runner: seeded churn traces through
/// `run_federation_trace`, which kills group `victim` mid-trace,
/// round-trips the durable snapshot through the `OUROSNAP` wire format
/// and rebuilds over the same heaps. Zero lost blocks, zero retired
/// ops, restart timed.
#[test]
fn federation_trace_runner_survives_mid_trace_restart() {
    let mut checked_ops = 0u64;
    for seed in 0..chaos_seeds() {
        let fed = FederationRouter::new(
            vec![
                small_group(RoutePolicy::RoundRobin),
                small_group(RoutePolicy::RoundRobin),
            ],
            1,
        );
        let trace = churn_trace(0xFEDE + seed, 48, 400, 8192);
        let victim = (seed % 2) as usize;
        let rep = run_federation_trace(&fed, 4, &trace, victim, 200)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(rep.lost_blocks, 0, "seed {seed}: blocks lost");
        assert_eq!(rep.fed_stats.restarts, 1, "seed {seed}");
        assert!(
            rep.events
                .iter()
                .any(|e| e.kind == FederationEventKind::Restarted),
            "seed {seed}"
        );
        let merged = ServiceTraceReport::merged(&rep.reports);
        assert_eq!(
            merged.retired_ops, 0,
            "seed {seed}: the restart must be invisible to clients"
        );
        assert_eq!(merged.alloc_failures, 0, "seed {seed}");
        assert_eq!(
            merged.allocs - merged.alloc_failures,
            merged.frees + rep.leftover,
            "seed {seed}: conservation"
        );
        for gi in 0..2 {
            let lin = fed.with_group(gi, |svc| svc.history()).unwrap();
            checked_ops += common::check_history(&lin);
        }
        fed.shutdown();
    }
    common::assert_chaos_coverage(checked_ops, chaos_seeds());
}

/// A stale name promised before the kill is honored after the restore:
/// alloc, migrate (forwarding entry), restart the group from its
/// handoff, then free the old federated name — it must forward to the
/// migrated copy exactly once, in the successor process.
#[test]
fn restart_honors_stale_names_through_restored_table() {
    let fed = FederationRouter::new(vec![small_group(RoutePolicy::RoundRobin)], 1);
    let c = fed.client();
    let a = c.alloc(2048).unwrap();
    let local = a.strip_group();
    let moved = fed
        .with_group(0, |svc| {
            svc.set_forwarding_grace(Duration::from_secs(120));
            svc.migrate(local).unwrap()
        })
        .unwrap();
    assert_ne!(moved, local);
    restart_in_place(&fed, 0).unwrap();
    c.free(a).expect("stale name must forward through the restored table");
    // Exactly once: the restored entry was consumed by that free.
    let again = c.free(a);
    assert!(
        matches!(again, Err(AllocError::InvalidFree(_))),
        "second free of the forwarded name must reject, got {again:?}"
    );
    fed.shutdown();
}

// ---------------------------------------------------------------------------
// Snapshot robustness (satellite: corrupt snapshots reject, never panic)
// ---------------------------------------------------------------------------

/// Every truncation of a real snapshot, any flipped byte, and a
/// topology-mismatched restore all yield the deterministic
/// `AllocError::SnapshotCorrupt` — never a panic, never a silently
/// empty forwarding table.
#[test]
fn corrupt_snapshots_reject_deterministically() {
    let svc = small_group(RoutePolicy::RoundRobin);
    svc.set_forwarding_grace(Duration::from_secs(120));
    let c = svc.client();
    let a = c.alloc(4096).unwrap();
    svc.migrate(a).unwrap();
    let snap = svc.snapshot_state();
    assert!(!snap.entries.is_empty(), "need a forwarding entry to protect");
    let enc = snap.encode();

    // Round-trip sanity.
    assert_eq!(ServiceSnapshot::decode(enc.as_bytes()).unwrap(), snap);

    // Truncation at every byte boundary.
    for cut in 0..enc.len() {
        assert_eq!(
            ServiceSnapshot::decode(&enc.as_bytes()[..cut]),
            Err(AllocError::SnapshotCorrupt),
            "truncation at {cut} must reject"
        );
    }
    // Any single flipped byte.
    for i in 0..enc.len() {
        let mut bad = enc.clone().into_bytes();
        bad[i] ^= 0x01;
        assert_eq!(
            ServiceSnapshot::decode(&bad),
            Err(AllocError::SnapshotCorrupt),
            "flipped byte {i} must reject"
        );
    }

    // Restoring onto a mismatched topology refuses wholesale: a
    // three-member group cannot half-apply a two-member snapshot.
    let other = AllocService::start_named_group(
        &[
            ("t2000", Variant::Page),
            ("t2000", Variant::Page),
            ("t2000", Variant::Page),
        ],
        &HeapConfig::test_small(),
        BatchPolicy::default(),
        RoutePolicy::RoundRobin,
        Arc::new(Cuda::new()),
    );
    assert_eq!(
        other.restore_state(&snap),
        Err(AllocError::SnapshotCorrupt)
    );
    other.shutdown();
    // And `start_group_restored` refuses before starting anything.
    let handoff = Handoff::from_snapshot(snap.clone());
    assert!(handoff.rebuild_members().is_empty());
    let err = AllocService::start_group_restored(
        vec![],
        BatchPolicy::default(),
        RoutePolicy::RoundRobin,
        &handoff,
    )
    .err();
    assert_eq!(err, Some(AllocError::SnapshotCorrupt));

    // Persistence path: save/load round-trips; a missing file rejects.
    let path = std::env::temp_dir().join(format!(
        "ouro_snap_test_{}.ourosnap",
        std::process::id()
    ));
    snap.save(&path).unwrap();
    assert_eq!(ServiceSnapshot::load(&path).unwrap(), snap);
    std::fs::remove_file(&path).unwrap();
    assert_eq!(
        ServiceSnapshot::load(&path),
        Err(AllocError::SnapshotCorrupt)
    );
    // The service still runs; the live block is still freeable.
    c.free(a).unwrap_or_else(|e| {
        // `a` migrated: the stale name forwards.
        panic!("free after snapshot games: {e}")
    });
    svc.shutdown();
}

// ---------------------------------------------------------------------------
// Client-side transient-failure retry (satellite)
// ---------------------------------------------------------------------------

/// A fully-dead group surfaces `DeviceRetired` only after the bounded
/// backoff schedule runs dry — and the schedule is exactly
/// base, min(2·base, cap), min(4·base, cap), … on the injected clock.
#[test]
fn retry_backoff_is_bounded_and_counted() {
    let svc = AllocService::start_named_group(
        &[("t2000", Variant::Page)],
        &HeapConfig::test_small(),
        BatchPolicy::default(),
        RoutePolicy::RoundRobin,
        Arc::new(Cuda::new()),
    );
    svc.retire_device(0);
    let clock = Arc::new(FakeClock::new());
    let mut c = svc.client();
    c.set_retry(RetryPolicy {
        max_retries: 3,
        base: Duration::from_micros(100),
        cap: Duration::from_micros(150),
    });
    c.set_retry_clock(clock.clone());
    assert_eq!(c.alloc(512), Err(AllocError::DeviceRetired));
    // 100µs, then 200µs capped to 150, then 150 again.
    assert_eq!(clock.now(), Duration::from_micros(100 + 150 + 150));
    assert_eq!(
        svc.snapshot().alloc_retries,
        3,
        "every re-attempt is counted"
    );

    // RetryPolicy::none() restores the old fail-fast behavior.
    let mut fast = svc.client();
    fast.set_retry(RetryPolicy::none());
    fast.set_retry_clock(clock.clone());
    let before = clock.now();
    assert_eq!(fast.alloc(512), Err(AllocError::DeviceRetired));
    assert_eq!(clock.now(), before, "no-retry policy must not sleep");
    assert_eq!(svc.snapshot().alloc_retries, 3, "and not count retries");
    svc.shutdown();
}

/// A clock that readmits the dead member from a helper thread during
/// the first backoff sleep — the deterministic "transient outage heals
/// mid-retry" scenario.
struct ReadmitOnSleep {
    ask: Mutex<mpsc::Sender<()>>,
    done: Mutex<mpsc::Receiver<()>>,
}

impl Clock for ReadmitOnSleep {
    fn now(&self) -> Duration {
        Duration::ZERO
    }
    fn sleep(&self, _d: Duration) {
        // Hand the baton to the repair thread and wait for it.
        let _ = self.ask.lock().unwrap().send(());
        let _ = self.done.lock().unwrap().recv();
    }
}

#[test]
fn retry_recovers_when_the_outage_heals_mid_backoff() {
    let svc = AllocService::start_named_group(
        &[("t2000", Variant::Page)],
        &HeapConfig::test_small(),
        BatchPolicy::default(),
        RoutePolicy::RoundRobin,
        Arc::new(Cuda::new()),
    );
    svc.retire_device(0);
    let (ask_tx, ask_rx) = mpsc::channel();
    let (done_tx, done_rx) = mpsc::channel();
    let mut c = svc.client();
    c.set_retry(RetryPolicy::default());
    c.set_retry_clock(Arc::new(ReadmitOnSleep {
        ask: Mutex::new(ask_tx),
        done: Mutex::new(done_rx),
    }));
    let got = std::thread::scope(|s| {
        let svc = &svc;
        s.spawn(move || {
            // Repair the member during the client's first backoff,
            // then exit: dropping `done_tx` makes any later sleep (on
            // success there are none) return immediately instead of
            // blocking the scope join.
            if ask_rx.recv().is_ok() {
                svc.readmit_device(0).expect("readmit");
                let _ = done_tx.send(());
            }
        });
        c.alloc(512)
    });
    let addr = got.expect("retry must succeed after the readmit");
    assert_eq!(svc.snapshot().alloc_retries, 1, "one re-attempt sufficed");
    let c2 = svc.client();
    c2.free(addr).unwrap();
    svc.shutdown();
}
