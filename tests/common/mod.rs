//! Shared chaos-suite plumbing for the `OURO_LIN` analysis leg: each
//! suite harvests its service's recorded history, runs the
//! linearizability checker over it, and asserts the process-global
//! lock-order graph stayed acyclic. With `OURO_LIN` unset the helpers
//! are no-ops, so the suites cost nothing extra in the default tier-1
//! run.
#![allow(dead_code)]

use std::sync::Arc;

use ouroboros_tpu::check::history::HistoryRecorder;
use ouroboros_tpu::check::{linearize, lockgraph};

/// Whether `OURO_LIN` is armed (same contract as
/// `HistoryRecorder::from_env`).
pub fn lin_armed() -> bool {
    std::env::var("OURO_LIN").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Harvest and linearize-check a service's recorded history (no-op
/// when the recorder is absent, i.e. `OURO_LIN` unset). Returns the
/// number of checked ops so the caller can accumulate coverage. A
/// violation fails the test with the checker's minimal
/// non-linearizable window.
pub fn check_history(lin: &Option<Arc<HistoryRecorder>>) -> u64 {
    let Some(lin) = lin else { return 0 };
    let history = lin.harvest();
    match linearize::check(&history) {
        Ok(report) => {
            assert_eq!(report.ops, history.len());
            lockgraph::assert_acyclic();
            history.len() as u64
        }
        Err(v) => panic!("linearizability violation:\n{v}"),
    }
}

/// The chaos-scale coverage gate: at CI's `OURO_CHAOS_SEEDS=8` with
/// `OURO_LIN=1`, the suite must have pushed a real history through the
/// checker — tens of thousands of ops, not a handful.
pub fn assert_chaos_coverage(total_ops: u64, seeds: u64) {
    if !lin_armed() || seeds < 8 {
        return;
    }
    assert!(
        total_ops >= 10_000,
        "chaos run lin-checked only {total_ops} ops at {seeds} seeds \
         (expected >= 10k)"
    );
}
