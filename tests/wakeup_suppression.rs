//! Ring wakeup suppression (EVENT_IDX discipline) — integration
//! coverage for the notification protocol: a parked waiter must wake
//! on the completion that crosses its watermark exactly (no lost
//! notification), doorbells park while the lane worker is off
//! dispatching, suppressed-wakeup tallies move under an 8-client
//! depth-32 churn, and the `eager_notify` baseline never suppresses.
//!
//! `OURO_CHAOS_SEEDS` (default 2) controls how many RNG seeds the
//! churn test loops; CI runs this file at 8 seeds, and the analysis
//! job re-runs it under `OURO_SAN=1` so every dispatch behind the
//! suppressed broadcasts is still double-entry bookkept, and under
//! `OURO_LIN=1` so each seed's recorded op history linearizes (see
//! `common::check_history`).

mod common;

use std::sync::{Arc, Mutex};
use std::time::Duration;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::ring::Ticket;
use ouroboros_tpu::coordinator::router::RoutePolicy;
use ouroboros_tpu::coordinator::service::{AllocService, ServiceClient};
use ouroboros_tpu::ouroboros::{
    build_allocator, GlobalAddr, HeapConfig, Variant,
};
use ouroboros_tpu::simt::{Device, DeviceProfile};
use ouroboros_tpu::util::rng::Rng;

fn chaos_seeds() -> u64 {
    std::env::var("OURO_CHAOS_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2)
        .max(1)
}

fn single(policy: BatchPolicy) -> AllocService {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    let alloc = build_allocator(
        Variant::Page,
        &HeapConfig { num_chunks: 512, ..HeapConfig::default() },
    );
    AllocService::start(device, alloc, policy)
}

/// The same heterogeneous 3-device group the failover and lease suites
/// churn: two t2000s around an Iris Xe.
fn hetero_group(route: RoutePolicy) -> AllocService {
    AllocService::start_named_group(
        &[
            ("t2000", Variant::Page),
            ("iris-xe", Variant::Chunk),
            ("t2000", Variant::VlChunk),
        ],
        &HeapConfig { num_chunks: 512, ..HeapConfig::default() },
        BatchPolicy::default(),
        route,
        Arc::new(Cuda::new()),
    )
}

/// Non-blocking reap loop: spin `poll` until every ticket completes,
/// never registering a ring waiter — the shape whose broadcasts the
/// suppression discipline elides entirely.
fn poll_reap(c: &ServiceClient, mut pending: Vec<Ticket>) -> Vec<GlobalAddr> {
    let mut addrs = Vec::new();
    while !pending.is_empty() {
        pending.retain(|&t| match c.poll(t) {
            Some(comp) => {
                addrs.push(comp.into_alloc().expect("alloc completion"));
                false
            }
            None => true,
        });
        if !pending.is_empty() {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    addrs
}

/// The no-lost-notification half of the protocol, end to end through
/// the service: a waiter parks in `ring.wait` while the lane worker is
/// wedged pre-dispatch (stall injection), so its published watermark
/// equals the current used index — the completion that eventually
/// lands crosses that watermark by exactly one, the EVENT_IDX boundary
/// case, and the waiter must wake. While the worker is wedged (off
/// "dispatching"), the batcher doorbell is parked at `u32::MAX`, so
/// the extra submits that pile up behind it stay deterministically
/// silent.
#[test]
fn parked_waiter_wakes_at_the_watermark_boundary() {
    let svc = single(BatchPolicy::default());
    svc.inject_stall(0, true);

    let woke: Mutex<Option<GlobalAddr>> = Mutex::new(None);
    std::thread::scope(|s| {
        let svc_ref = &svc;
        let woke = &woke;
        s.spawn(move || {
            let w = svc_ref.client();
            let t = w.submit_alloc(64).expect("submit under stall");
            // The worker picks the batch up and wedges before dispatch;
            // this parks with watermark == used index (the boundary).
            let a = w
                .wait(t)
                .expect("parked waiter must wake, not hang")
                .into_alloc()
                .expect("alloc");
            *woke.lock().unwrap() = Some(a);
        });
        // Let the worker claim the batch and wedge, and the waiter park.
        std::thread::sleep(Duration::from_millis(100));

        // Submits landing while the lane worker is off the batcher must
        // not ring: nobody is listening (doorbell parked at u32::MAX,
        // no phase-1 parker on this lane).
        let c = svc.client();
        let before = svc.snapshot();
        let mut late = Vec::new();
        for _ in 0..3 {
            late.push(c.submit_alloc(64).expect("submit under stall"));
        }
        let after = svc.snapshot();
        assert_eq!(
            after.doorbell_suppressed - before.doorbell_suppressed,
            3,
            "mid-dispatch submits must stay silent"
        );

        // Release the worker: the wedged batch dispatches, its
        // completion crosses the waiter's watermark, the waiter wakes.
        svc.inject_stall(0, false);
        for t in late {
            let a = c.wait(t).expect("straggler").into_alloc().expect("alloc");
            c.free(a).expect("free");
        }
    });

    let addr = woke.into_inner().unwrap().expect("waiter never woke");
    let snap = svc.snapshot();
    assert!(
        snap.wakeup_delivered >= 1,
        "the boundary-crossing completion must broadcast: {snap:?}"
    );
    svc.client().free(addr).expect("free the waited block");

    let snap = svc.snapshot();
    assert_eq!(snap.allocs, snap.frees, "ring-level leak: {snap:?}");
    let allocators = svc.allocators();
    drop(svc);
    assert!(allocators[0].debug_consistent());
}

/// A client that only ever polls registers no waiter and publishes no
/// watermark: with the ring's watermark parked at idle, every
/// completion broadcast is elided — deterministically zero condvar
/// wakeups across a depth-32 alloc burst and its matching frees.
#[test]
fn poll_only_pipeline_suppresses_every_broadcast() {
    let svc = single(BatchPolicy::default());
    let c = svc.client();

    let mut tickets = Vec::new();
    for _ in 0..32 {
        tickets.push(c.submit_alloc(64).expect("submit"));
    }
    let addrs = poll_reap(&c, tickets);
    let snap = svc.snapshot();
    assert_eq!(
        snap.wakeup_delivered, 0,
        "no waiter ever registered; every broadcast must be elided"
    );
    assert!(snap.wakeup_suppressed >= 1, "the burst completed: {snap:?}");

    let mut frees = Vec::new();
    for a in addrs {
        frees.push(c.submit_free(a).expect("submit free"));
    }
    let mut pending = frees;
    while !pending.is_empty() {
        pending.retain(|&t| match c.poll(t) {
            Some(comp) => {
                comp.into_free().expect("free completion");
                false
            }
            None => true,
        });
        if !pending.is_empty() {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let snap = svc.snapshot();
    assert_eq!(snap.wakeup_delivered, 0, "frees poll-reaped too: {snap:?}");
    assert_eq!(snap.allocs, snap.frees, "ring-level leak: {snap:?}");

    let allocators = svc.allocators();
    drop(c);
    drop(svc);
    assert!(allocators[0].debug_consistent());
}

/// The acceptance churn: 8 clients, depth-32 pipelines, alternating
/// blocking (`wait_all`) and poll-spin reaps across the heterogeneous
/// group. Both suppression tallies must move — broadcasts elided while
/// nobody is parked, doorbells elided while workers drain — while
/// blocked waiters still see every completion (the churn would hang
/// otherwise). A single-threaded quiet tail then pins the ring-side
/// assertion deterministically: bursts reaped by poll alone, each
/// fully drained before the next, can spuriously broadcast at most
/// once per lane.
#[test]
fn depth32_churn_moves_the_suppression_tallies() {
    let policies = RoutePolicy::all();
    let mut checked_ops = 0u64;
    for seed in 0..chaos_seeds() {
        let route = policies[(seed as usize) % policies.len()];
        let svc = hetero_group(route);
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let c = svc.client();
                s.spawn(move || {
                    let mut rng = Rng::new(0xD00B + seed * 65_537 + t * 7919);
                    for round in 0..4 {
                        let mut tickets = Vec::new();
                        for _ in 0..32 {
                            let size = rng.range(1, 8192) as u32;
                            tickets.push(c.submit_alloc(size).unwrap_or_else(
                                |e| panic!("{}: alloc: {e}", route.id()),
                            ));
                        }
                        let addrs = if round % 2 == 0 {
                            // Poll rounds: long windows with no parked
                            // waiter — broadcast-suppression fodder.
                            poll_reap(&c, tickets)
                        } else {
                            c.wait_all()
                                .into_iter()
                                .map(|(_, r)| {
                                    r.unwrap_or_else(|e| {
                                        panic!("{}: wait: {e}", route.id())
                                    })
                                    .into_alloc()
                                    .expect("alloc")
                                })
                                .collect()
                        };
                        for a in addrs {
                            c.submit_free(a).unwrap_or_else(|e| {
                                panic!("{}: free({a}): {e}", route.id())
                            });
                        }
                        for (_, r) in c.wait_all() {
                            r.unwrap_or_else(|e| {
                                panic!("{}: free wait: {e}", route.id())
                            })
                            .into_free()
                            .expect("free");
                        }
                    }
                });
            }
        });

        let snap = svc.snapshot();
        assert!(
            snap.wakeup_suppressed > 0,
            "{}: seed {seed}: no broadcast was ever elided: {snap:?}",
            route.id()
        );
        assert!(
            snap.wakeup_delivered > 0,
            "{}: seed {seed}: blocked waiters must still be woken",
            route.id()
        );
        assert!(
            snap.doorbell_suppressed > 0,
            "{}: seed {seed}: no doorbell was ever elided: {snap:?}",
            route.id()
        );
        assert!(
            snap.doorbell_delivered > 0,
            "{}: seed {seed}: parked workers must still be kicked",
            route.id()
        );
        assert_eq!(
            snap.allocs, snap.frees,
            "{}: seed {seed}: ring-level leak",
            route.id()
        );

        // Quiet tail: 4 poll-reaped bursts on one size class, each
        // drained before the next so their completions are distinct
        // `complete_bulk` events. Only a stale watermark left exactly
        // at a ring's used index can deliver, and only once per ring —
        // with 4 bursts over 3 members, pigeonhole guarantees some
        // ring sees two events, so the suppressed tally must grow.
        let before = svc.snapshot();
        let c = svc.client();
        let mut tail = Vec::new();
        for _ in 0..4 {
            let mut burst = Vec::new();
            for _ in 0..8 {
                burst.push(c.submit_alloc(64).expect("tail alloc"));
            }
            tail.extend(poll_reap(&c, burst));
        }
        let after = svc.snapshot();
        assert!(
            after.wakeup_suppressed > before.wakeup_suppressed,
            "{}: seed {seed}: quiet-tail broadcasts must be elided",
            route.id()
        );
        for a in tail {
            c.free(a).expect("tail free");
        }

        let snap = svc.snapshot();
        assert_eq!(
            snap.allocs, snap.frees,
            "{}: seed {seed}: ring-level leak after tail",
            route.id()
        );
        checked_ops += common::check_history(&svc.history());
        let allocators = svc.allocators();
        drop(c);
        drop(svc);
        for (i, a) in allocators.iter().enumerate() {
            assert!(
                a.debug_consistent(),
                "{}: device {i} inconsistent (seed {seed})",
                route.id()
            );
        }
    }
    common::assert_chaos_coverage(checked_ops, chaos_seeds());
}

/// `BatchPolicy::eager_notify` restores the pre-suppression baseline
/// bit for bit: every completion batch broadcasts and every submit
/// rings the worker doorbell, even across the poll-only shape the
/// default discipline silences completely.
#[test]
fn eager_baseline_never_suppresses() {
    let svc = single(BatchPolicy {
        eager_notify: true,
        ..BatchPolicy::default()
    });
    let c = svc.client();

    for _ in 0..2 {
        for _ in 0..32 {
            c.submit_alloc(64).expect("submit");
        }
        let addrs: Vec<GlobalAddr> = c
            .wait_all()
            .into_iter()
            .map(|(_, r)| r.expect("wait").into_alloc().expect("alloc"))
            .collect();
        for a in addrs {
            c.submit_free(a).expect("free");
        }
        for (_, r) in c.wait_all() {
            r.expect("wait").into_free().expect("free");
        }
    }
    // The poll-only shape: the default discipline elides every
    // broadcast here; the eager baseline must elide none.
    let mut tickets = Vec::new();
    for _ in 0..32 {
        tickets.push(c.submit_alloc(64).expect("submit"));
    }
    for a in poll_reap(&c, tickets) {
        c.free(a).expect("free");
    }

    let snap = svc.snapshot();
    assert_eq!(snap.wakeup_suppressed, 0, "eager ring suppressed: {snap:?}");
    assert_eq!(snap.doorbell_suppressed, 0, "eager doorbell suppressed");
    assert!(snap.wakeup_delivered > 0);
    assert!(snap.doorbell_delivered > 0);
    assert_eq!(snap.allocs, snap.frees, "ring-level leak: {snap:?}");
}
