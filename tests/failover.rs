//! Device failover + live-set migration under concurrent churn — the
//! chaos harness behind CI's `chaos` job.
//!
//! `OURO_CHAOS_SEEDS` (default 2) controls how many RNG seeds the
//! randomized drain-race tests run; CI sets 8 so nondeterministic
//! interleavings get real coverage on every push. Under `OURO_LIN=1`
//! each seed's recorded op history is additionally fed through the
//! linearizability checker (see `common::check_history`).

mod common;

use std::collections::HashSet;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::driver::{
    failover_quiesce_timeout, run_failover_trace, ServiceTraceReport,
};
use ouroboros_tpu::coordinator::router::{DeviceState, RoutePolicy};
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::coordinator::workload::churn_trace;
use ouroboros_tpu::ouroboros::{AllocError, GlobalAddr, HeapConfig, Variant};
use ouroboros_tpu::simt::{Device, DeviceProfile};
use ouroboros_tpu::util::rng::Rng;

fn chaos_seeds() -> u64 {
    std::env::var("OURO_CHAOS_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2)
        .max(1)
}

/// A heterogeneous 3-device group: two t2000s around an Iris Xe, each
/// member a different allocator variant over its own heap.
fn hetero_group(route: RoutePolicy) -> AllocService {
    AllocService::start_named_group(
        &[
            ("t2000", Variant::Page),
            ("iris-xe", Variant::Chunk),
            ("t2000", Variant::VlChunk),
        ],
        &HeapConfig { num_chunks: 512, ..HeapConfig::default() },
        BatchPolicy::default(),
        route,
        Arc::new(Cuda::new()),
    )
}

/// Block until the victim's lanes are quiet (event-driven condvar
/// wait, deadline from `OURO_QUIESCE_MS`), then retire — the operator
/// sequence `run_failover_trace` also uses.
fn quiesce_then_retire(svc: &AllocService, victim: usize) {
    svc.wait_lanes_quiet(victim, failover_quiesce_timeout());
    svc.retire_device(victim);
}

/// The acceptance churn: 8 clients share one pool of live allocations
/// across a heterogeneous 3-device group while the controller drains
/// and retires a member mid-churn. Invariants, per seed and policy:
///
/// * the global live set never holds a duplicate address, across the
///   migration included;
/// * every free succeeds — stale frees of migrated addresses are
///   forwarded (exactly once each: forwarded count == migrated count);
/// * nothing is lost: no client ever observes `DeviceRetired`, the
///   drain reports zero unplaceable pages, and after the final drain
///   every member's allocator counters balance and its heap passes the
///   consistency check.
#[test]
fn drain_and_retire_mid_churn_preserves_live_set() {
    let policies = RoutePolicy::all();
    let mut checked_ops = 0u64;
    for seed in 0..chaos_seeds() {
        let route = policies[(seed as usize) % policies.len()];
        let svc = hetero_group(route);
        svc.set_forwarding_grace(Duration::from_secs(120));
        let victim = 1usize;
        let pool: Mutex<(Vec<GlobalAddr>, HashSet<GlobalAddr>)> =
            Mutex::new((Vec::new(), HashSet::new()));
        let drain_report = Mutex::new(None);
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let c = svc.client();
                let pool = &pool;
                s.spawn(move || {
                    let mut rng = Rng::new(0xFA11 + seed * 65_537 + t * 7919);
                    for _ in 0..200 {
                        if rng.chance(0.55) {
                            let size = rng.range(1, 8192) as u32;
                            let addr = c.alloc(size).unwrap_or_else(|e| {
                                panic!("{}: alloc({size}): {e}", route.id())
                            });
                            let mut g = pool.lock().unwrap();
                            assert!(
                                g.1.insert(addr),
                                "{}: duplicate live address {addr}",
                                route.id()
                            );
                            g.0.push(addr);
                        } else {
                            let victim_addr = {
                                let mut g = pool.lock().unwrap();
                                if g.0.is_empty() {
                                    continue;
                                }
                                let i = rng.below(g.0.len() as u64) as usize;
                                let a = g.0.swap_remove(i);
                                assert!(g.1.remove(&a));
                                a
                            };
                            // Possibly a stale name by now (migrated
                            // mid-churn): must still free exactly once.
                            c.free(victim_addr).unwrap_or_else(|e| {
                                panic!(
                                    "{}: free({victim_addr}): {e}",
                                    route.id()
                                )
                            });
                        }
                    }
                });
            }
            let drain_report = &drain_report;
            let svc_ref = &svc;
            s.spawn(move || {
                // Fire mid-churn: wait for real traffic first.
                while svc_ref.stats().ops.load(Ordering::Relaxed) < 150 {
                    std::thread::sleep(Duration::from_micros(200));
                }
                let rep = svc_ref.drain_device(victim).expect("drain");
                quiesce_then_retire(svc_ref, victim);
                *drain_report.lock().unwrap() = Some(rep);
            });
        });
        let drain = drain_report.into_inner().unwrap().expect("controller ran");
        assert_eq!(
            drain.failed, 0,
            "{}: live blocks could not be rehomed",
            route.id()
        );
        assert_eq!(
            drain.unquiesced, 0,
            "{}: drain proceeded past in-flight allocs",
            route.id()
        );
        // Migrated copies are unique, live on healthy members only.
        let mut to: Vec<GlobalAddr> =
            drain.migrated.iter().map(|m| m.to).collect();
        let n_migrated = to.len();
        to.sort_unstable();
        to.dedup();
        assert_eq!(to.len(), n_migrated, "{}: duplicate copies", route.id());
        for m in &drain.migrated {
            assert_eq!(m.from.device() as usize, victim);
            assert_ne!(m.to.device() as usize, victim);
        }

        // Drain the surviving pool: every entry must free cleanly,
        // stale names through the forwarding table.
        let drainer = svc.client();
        let leftovers = std::mem::take(&mut pool.lock().unwrap().0);
        for a in leftovers {
            drainer.free(a).unwrap_or_else(|e| {
                panic!("{}: drain free({a}): {e}", route.id())
            });
        }

        let stats = svc.stats();
        assert_eq!(
            stats.forwarded_frees.load(Ordering::Relaxed),
            n_migrated as u64,
            "{}: every migrated address must forward exactly once",
            route.id()
        );
        assert_eq!(stats.retired_ops.load(Ordering::Relaxed), 0,
            "{}: a clean drain+quiesce+retire loses nothing", route.id());
        let snap = svc.snapshot();
        assert_eq!(snap.devices[victim].state, "retired");
        assert_eq!(snap.allocs, snap.frees, "{}: {snap:?}", route.id());

        // Under OURO_LIN=1: the whole seed's history — churn, drain
        // migrations, forwarded frees — must linearize.
        checked_ops += common::check_history(&svc.history());

        let allocators = svc.allocators();
        drop(svc);
        for (i, a) in allocators.iter().enumerate() {
            assert!(
                a.debug_consistent(),
                "{}: device {i} inconsistent after failover",
                route.id()
            );
            assert_eq!(
                a.counters().mallocs.load(Ordering::Relaxed),
                a.counters().frees.load(Ordering::Relaxed),
                "{}: device {i} unbalanced after failover (seed {seed})",
                route.id()
            );
        }
    }
    common::assert_chaos_coverage(checked_ops, chaos_seeds());
}

/// The pipelined variant of the acceptance criterion: 8 async clients
/// drive seeded churn traces at depth while `run_failover_trace` kills
/// member 1 mid-trace. Zero `DeviceRetired` observations and zero
/// unmigrated blocks.
#[test]
fn failover_trace_runner_survives_mid_trace_kill() {
    let mut checked_ops = 0u64;
    for seed in 0..chaos_seeds() {
        let svc = hetero_group(RoutePolicy::RoundRobin);
        svc.set_forwarding_grace(Duration::from_secs(120));
        let trace = churn_trace(0xD15C0 + seed, 48, 400, 8192);
        let rep = run_failover_trace(&svc, 8, &trace, 16, 1, 400)
            .expect("failover trace");
        let agg = ServiceTraceReport::merged(&rep.reports);
        assert_eq!(agg.retired_ops, 0, "seed {seed}: lost ops");
        assert_eq!(agg.alloc_failures, 0, "seed {seed}");
        assert_eq!(rep.drain.failed, 0, "seed {seed}");
        assert_eq!(rep.drain.unquiesced, 0, "seed {seed}");
        assert_eq!(rep.retire.device, 1);
        assert_eq!(svc.device_state(1), DeviceState::Retired);
        checked_ops += common::check_history(&svc.history());
        let allocators = svc.allocators();
        drop(svc);
        for (i, a) in allocators.iter().enumerate() {
            assert!(a.debug_consistent(), "device {i}, seed {seed}");
            assert_eq!(
                a.counters().mallocs.load(Ordering::Relaxed),
                a.counters().frees.load(Ordering::Relaxed),
                "device {i} unbalanced, seed {seed}"
            );
        }
    }
    common::assert_chaos_coverage(checked_ops, chaos_seeds());
}

/// Deterministic in-flight failure: ops parked in a retiring member's
/// lanes resolve to `DeviceRetired` completions — the right completion
/// kind, never a hang, never `ServiceDown`.
#[test]
fn in_flight_tickets_fail_with_device_retired() {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    let alloc = ouroboros_tpu::ouroboros::build_allocator(
        Variant::Page,
        &HeapConfig::test_small(),
    );
    // A long straggler window parks submissions in the avail ring long
    // enough for the retire to win the race deterministically.
    let policy = BatchPolicy {
        window: Duration::from_millis(500),
        max_batch: 64,
        ..BatchPolicy::default()
    };
    let svc = AllocService::start(device, alloc, policy);
    let c = svc.client();
    let tickets: Vec<_> =
        (0..4).map(|_| c.submit_alloc(256).unwrap()).collect();
    let report = svc.retire_device(0);
    assert_eq!(report.failed_inflight, 4);
    for t in tickets {
        let completion = c.wait(t).expect("completion, not a hang");
        assert_eq!(
            completion.into_alloc().unwrap_err(),
            AllocError::DeviceRetired
        );
    }
    // The whole group is dead now: submits fail deterministically too.
    assert_eq!(c.alloc(64), Err(AllocError::DeviceRetired));
    assert_eq!(svc.healthy_devices(), 0);
}

/// Post-retirement placement: under every routing policy, no client —
/// whatever its affinity — is ever routed to the dead member, and
/// frees aimed at it are rejected deterministically.
#[test]
fn post_retirement_submits_never_route_to_dead_member() {
    for route in RoutePolicy::all() {
        let svc = hetero_group(route);
        let clients: Vec<_> = (0..3).map(|_| svc.client()).collect();
        let retired = svc.retire_device(1);
        assert_eq!(retired.device, 1);
        for c in &clients {
            for _ in 0..6 {
                let a = c.alloc(1000).unwrap_or_else(|e| {
                    panic!("{}: alloc after retire: {e}", route.id())
                });
                assert_ne!(
                    a.device(),
                    1,
                    "{}: routed to the dead member",
                    route.id()
                );
                c.free(a).unwrap();
            }
        }
        // A free tagged for the dead member (no forwarding entry).
        let phantom = GlobalAddr::new(1, 64);
        assert_eq!(
            clients[0].free(phantom),
            Err(AllocError::DeviceRetired),
            "{}",
            route.id()
        );
        let snap = svc.snapshot();
        assert_eq!(snap.devices[1].ops, 0, "{}: {snap:?}", route.id());
        assert_eq!(snap.devices[1].state, "retired", "{}", route.id());
    }
}

/// Migration end-to-end through a live service: the payload moves with
/// the block, the stale name forwards exactly once inside the grace
/// window, and a second stale free is rejected with the tagged
/// `InvalidFree`.
#[test]
fn stale_free_forwarded_exactly_once_within_grace() {
    let svc = AllocService::start_named_group(
        &[("t2000", Variant::Page), ("t2000", Variant::Page)],
        &HeapConfig::test_small(),
        BatchPolicy::default(),
        RoutePolicy::ClientAffinity,
        Arc::new(Cuda::new()),
    );
    svc.set_forwarding_grace(Duration::from_secs(60));
    let c = svc.client(); // affinity 0
    let a = c.alloc(1024).unwrap();
    assert_eq!(a.device(), 0);
    // Stamp a recognisable payload into the source block.
    let src_heap = svc.allocator_of(0).heap().clone();
    let b = Cuda::new();
    let ctx = ouroboros_tpu::simt::DevCtx::new(&b, 1000.0, 0);
    for w in 0..256usize {
        src_heap.write_word(&ctx, (a.local() / 4) as usize + w, 0xC0DE + w as u32);
    }

    let new = svc.migrate(a).expect("migrate");
    assert_eq!(new.device(), 1, "only healthy other member");
    assert_eq!(svc.stats().migrations.load(Ordering::Relaxed), 1);
    assert_eq!(svc.forwarding_entries(), 1);
    // Payload travelled with the block.
    let dst_heap = svc.allocator_of(1).heap().clone();
    for w in 0..256usize {
        assert_eq!(
            dst_heap.read_word(&ctx, (new.local() / 4) as usize + w),
            0xC0DE + w as u32,
            "payload word {w} lost in migration"
        );
    }

    // First stale free: forwarded to the new home, exactly once.
    c.free(a).expect("stale free inside the grace window forwards");
    assert_eq!(svc.stats().forwarded_frees.load(Ordering::Relaxed), 1);
    // Second stale free: rejected with the *tagged* InvalidFree.
    assert_eq!(c.free(a), Err(AllocError::InvalidFree(a.raw())));
    // And the copy itself is gone (the forwarded free released it).
    assert_eq!(c.free(new), Err(AllocError::InvalidFree(new.raw())));
}

/// The forwarding-grace TOCTOU regression (the verdict is decided once,
/// at submit, and carried on the descriptor): a free the service
/// accepts *before* the block migrates — parked in the owner's lane by
/// a long batcher window — must follow the migration at dispatch even
/// when the grace window is zero. Under the old dispatch-time re-probe
/// the expired window turned this accepted op into a spurious
/// `InvalidFree` and leaked the migrated copy.
#[test]
fn queued_free_follows_migration_past_expired_grace() {
    // A long straggler window parks the free in the avail ring long
    // enough for the migration to win deterministically (the batcher's
    // idle early-close still waits window/4 = 200 ms; the migrate
    // below takes microseconds).
    let policy = BatchPolicy {
        window: Duration::from_millis(800),
        ..BatchPolicy::default()
    };
    let svc = AllocService::start_group(
        vec![
            (
                Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
                ouroboros_tpu::ouroboros::build_allocator(
                    Variant::Page,
                    &HeapConfig::test_small(),
                ),
            ),
            (
                Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
                ouroboros_tpu::ouroboros::build_allocator(
                    Variant::Page,
                    &HeapConfig::test_small(),
                ),
            ),
        ],
        policy,
        RoutePolicy::ClientAffinity,
    );
    svc.set_forwarding_grace(Duration::ZERO);
    let c = svc.client(); // affinity 0
    let a = c.alloc(1024).unwrap();
    assert_eq!(a.device(), 0);
    // Accept the free (Miss verdict — no entry yet), parked in lane.
    let t = c.submit_free(a).unwrap();
    // Migrate the block out from under the parked free. With grace
    // ZERO the entry is client-expired the instant it is published.
    let new = svc.migrate(a).expect("migrate");
    assert_eq!(new.device(), 1);
    // The parked free dispatches, finds the page gone, and must be
    // rescued to the copy — grace-exempt, because it was accepted
    // before the migration.
    c.wait(t)
        .expect("completion, not a hang")
        .into_free()
        .expect("queued free must follow the migration despite zero grace");
    assert_eq!(svc.stats().forwarded_frees.load(Ordering::Relaxed), 1);
    // The copy is gone (freed exactly once, by the rescue)...
    assert_eq!(c.free(new), Err(AllocError::InvalidFree(new.raw())));
    // ...and a *newly submitted* stale free still sees the unchanged
    // client-facing verdict: expired ⇒ tagged InvalidFree.
    assert_eq!(c.free(a), Err(AllocError::InvalidFree(a.raw())));
}

/// Outside the grace window a stale free is rejected, and the migrated
/// copy must be freed under its new name.
#[test]
fn expired_grace_window_rejects_with_tagged_invalid_free() {
    let svc = AllocService::start_named_group(
        &[("t2000", Variant::Page), ("t2000", Variant::Page)],
        &HeapConfig::test_small(),
        BatchPolicy::default(),
        RoutePolicy::ClientAffinity,
        Arc::new(Cuda::new()),
    );
    svc.set_forwarding_grace(Duration::ZERO);
    let c = svc.client();
    let a = c.alloc(512).unwrap();
    let new = svc.migrate(a).expect("migrate");
    std::thread::sleep(Duration::from_millis(2));
    assert_eq!(c.free(a), Err(AllocError::InvalidFree(a.raw())));
    assert_eq!(svc.stats().forwarded_frees.load(Ordering::Relaxed), 0);
    // The new name is the real one.
    c.free(new).expect("the migrated copy frees under its new name");
}

/// A group of one cannot rehome anything: drain reports the whole live
/// set as failed rather than pretending, and the sole member keeps
/// serving frees until retired.
#[test]
fn drain_without_healthy_target_strands_cleanly() {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    let alloc = ouroboros_tpu::ouroboros::build_allocator(
        Variant::Page,
        &HeapConfig::test_small(),
    );
    let svc = AllocService::start(device, alloc, BatchPolicy::default());
    let c = svc.client();
    let addrs: Vec<GlobalAddr> =
        (0..4).map(|_| c.alloc(1000).unwrap()).collect();
    let rep = svc.drain_device(0).expect("drain itself succeeds");
    assert_eq!(rep.migrated.len(), 0);
    assert_eq!(rep.failed, 4, "nowhere to put the live set");
    assert_eq!(svc.device_state(0), DeviceState::Draining);
    // Draining: no new placements anywhere (sole member), but frees
    // still land so the operator can unwind.
    assert_eq!(c.alloc(64), Err(AllocError::DeviceRetired));
    for a in addrs {
        c.free(a).unwrap();
    }
    // Draining a second time finds nothing left.
    let again = svc.drain_device(0).expect("re-drain");
    assert_eq!(again.failed, 0);
    // After the kill, even drain refuses.
    svc.retire_device(0);
    assert!(matches!(
        svc.drain_device(0),
        Err(AllocError::DeviceRetired)
    ));
}

/// Direct migration between named members, and the capacity-aware
/// router's view of it: moving blocks off a member lowers its gauge.
#[test]
fn migrate_to_targets_specific_member() {
    let svc = AllocService::start_named_group(
        &[("t2000", Variant::Page); 3],
        &HeapConfig::test_small(),
        BatchPolicy::default(),
        RoutePolicy::RoundRobin,
        Arc::new(Cuda::new()),
    );
    let c = svc.client();
    let a = loop {
        let a = c.alloc(256).unwrap();
        if a.device() == 0 {
            break a;
        }
        c.free(a).unwrap();
    };
    // Explicit target wins over the occupancy heuristic.
    let new = svc.migrate_to(a, 2).expect("migrate_to");
    assert_eq!(new.device(), 2);
    // Bad targets are rejected deterministically.
    assert_eq!(svc.migrate_to(new, 2), Err(AllocError::DeviceRetired));
    assert!(matches!(
        svc.migrate_to(GlobalAddr::new(0, 12), 1),
        Err(AllocError::InvalidFree(_))
    ));
    c.free(new).unwrap();
}
