//! Shadow-heap sanitizer integration: `OURO_SAN=1` service runs.
//!
//! Two halves. The positive half drives real service traffic — churn,
//! migration, forwarded frees, hard retires — and asserts the shadow
//! heap stays silent and empties (no false positives from the
//! dispatcher's out-of-order lanes). The meta-test half injects faults
//! at the shadow layer of a *running* service and asserts the
//! sanitizer's report: the panic names the violation and carries the
//! full per-address event history.
//!
//! `OURO_SAN` is process-global, so every service here is built under
//! one env lock; the variable only matters at construction time
//! (`ShadowHeap::from_env` is read once, in `start_group`).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::check::sanitizer::ShadowHeap;
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::router::RoutePolicy;
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::ouroboros::{AllocError, HeapConfig, Variant};

fn env_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Build a group with the sanitizer armed. The env var is only read at
/// construction, so the lock scope ends with the builder.
fn san_group(members: &[(&str, Variant)], route: RoutePolicy) -> AllocService {
    let guard = env_lock().lock().unwrap_or_else(|e| e.into_inner());
    std::env::set_var("OURO_SAN", "1");
    let svc = AllocService::start_named_group(
        members,
        &HeapConfig::test_small(),
        BatchPolicy::default(),
        route,
        Arc::new(Cuda::new()),
    );
    drop(guard);
    svc
}

fn shadow(svc: &AllocService) -> Arc<ShadowHeap> {
    svc.sanitizer().expect("OURO_SAN=1 must arm the shadow heap")
}

/// The panic payload a sanitizer violation raises (always a formatted
/// `String`).
fn panic_message(err: Box<dyn std::any::Any + Send>) -> String {
    match err.downcast::<String>() {
        Ok(s) => *s,
        Err(other) => match other.downcast::<&'static str>() {
            Ok(s) => (*s).to_string(),
            Err(_) => panic!("panic payload was not a string"),
        },
    }
}

// ---------------------------------------------------------------------------
// No false positives on real traffic
// ---------------------------------------------------------------------------

#[test]
fn clean_churn_is_report_free() {
    let svc = san_group(
        &[
            ("t2000", Variant::Page),
            ("iris-xe", Variant::Chunk),
            ("t2000", Variant::VlChunk),
        ],
        RoutePolicy::RoundRobin,
    );
    let san = shadow(&svc);
    let c = svc.client();
    // Several alloc/free waves so addresses recycle through the shadow
    // map (exercising the pending-window logic on reuse).
    for _ in 0..4 {
        let live: Vec<_> = (0..24).map(|_| c.alloc(512).unwrap()).collect();
        for a in live {
            c.free(a).unwrap();
        }
    }
    assert_eq!(san.live_count(), 0, "all generations resolved");
    drop(c);
    // Shutdown leak check runs in Drop; a report here fails the test.
    drop(svc);
}

#[test]
fn sanitizer_is_dormant_without_env() {
    let guard = env_lock().lock().unwrap_or_else(|e| e.into_inner());
    std::env::set_var("OURO_SAN", "0");
    let svc = AllocService::start_named_group(
        &[("t2000", Variant::Page)],
        &HeapConfig::test_small(),
        BatchPolicy::default(),
        RoutePolicy::ClientAffinity,
        Arc::new(Cuda::new()),
    );
    drop(guard);
    assert!(svc.sanitizer().is_none(), "OURO_SAN=0 must not arm");
    let c = svc.client();
    let a = c.alloc(256).unwrap();
    c.free(a).unwrap();
}

#[test]
fn migration_and_forwarded_free_are_tracked() {
    let svc = san_group(
        &[("t2000", Variant::Page), ("t2000", Variant::Page)],
        RoutePolicy::ClientAffinity,
    );
    svc.set_forwarding_grace(Duration::from_secs(60));
    let san = shadow(&svc);
    let c = svc.client(); // affinity 0
    let a = c.alloc(1024).unwrap();
    assert_eq!(a.device(), 0);

    let new = svc.migrate(a).expect("migrate");
    assert_eq!(new.device(), 1);
    // The shadow heap saw the re-homing: the old name is dead weight,
    // the copy is the live generation.
    assert_eq!(san.migrated_to(a), Some(new));
    assert_eq!(san.live_count(), 1, "exactly the copy is live");
    assert!(
        san.history(a).iter().any(|l| l.contains("migrated to")),
        "old-name history records the migration: {:?}",
        san.history(a)
    );

    // Stale free inside the grace window: forwarded to the copy, which
    // the shadow heap books as the copy's free — not the old name's.
    c.free(a).expect("stale free forwards within grace");
    assert_eq!(san.live_count(), 0);
    assert!(
        san.history(new).iter().any(|l| l.contains("freed")),
        "copy history records the forwarded free: {:?}",
        san.history(new)
    );
    drop(c);
    drop(svc); // clean shutdown: no leak report
}

#[test]
fn hard_retire_strands_blocks_without_leak_reports() {
    let svc = san_group(
        &[
            ("t2000", Variant::Page),
            ("t2000", Variant::Page),
            ("t2000", Variant::Page),
        ],
        RoutePolicy::RoundRobin,
    );
    let san = shadow(&svc);
    let c = svc.client();
    let live: Vec<_> = (0..9).map(|_| c.alloc(512).unwrap()).collect();
    assert!(live.iter().any(|a| a.device() == 1), "round-robin spread");

    // Hard retire with live blocks still on the member: stranded by
    // decision (ROADMAP documents this as the lossy path), which the
    // sanitizer must classify as stranded — not leaked.
    svc.begin_drain(1, Duration::from_millis(200)).expect("begin_drain");
    svc.retire_device(1);
    for &a in &live {
        if a.device() == 1 {
            assert_eq!(c.free(a), Err(AllocError::DeviceRetired));
            assert!(
                san.history(a).iter().any(|l| l.contains("stranded")),
                "stranded event recorded: {:?}",
                san.history(a)
            );
        } else {
            c.free(a).unwrap();
        }
    }
    assert_eq!(san.live_count(), 0, "stranded records are not live");
    drop(c);
    drop(svc); // must not report the stranded blocks as leaks
}

// ---------------------------------------------------------------------------
// Fault injection: the reports themselves
// ---------------------------------------------------------------------------

#[test]
fn injected_double_free_reports_full_history() {
    let svc = san_group(
        &[("t2000", Variant::Page)],
        RoutePolicy::ClientAffinity,
    );
    let san = shadow(&svc);
    let c = svc.client();
    let a = c.alloc(256).unwrap();
    c.free(a).unwrap();

    // Simulate a buggy lane reporting the same successful free twice.
    let err = catch_unwind(AssertUnwindSafe(|| san.on_free(a, a.device())))
        .expect_err("double free must panic");
    let msg = panic_message(err);
    assert!(msg.contains("OURO_SAN: double free"), "{msg}");
    assert!(msg.contains("address history"), "{msg}");
    assert!(msg.contains(&format!("{a}")), "report names the address: {msg}");

    // The history survives the report: mint, free, offending free.
    let hist = san.history(a);
    assert!(hist.len() >= 3, "{hist:?}");
    assert!(hist[0].contains("minted"), "{hist:?}");
    assert!(hist.iter().filter(|l| l.contains("freed")).count() >= 2, "{hist:?}");

    drop(c);
    drop(svc); // nothing live; shutdown stays clean
}

#[test]
fn injected_cross_device_free_reports_mismatch() {
    let svc = san_group(
        &[("t2000", Variant::Page), ("t2000", Variant::Page)],
        RoutePolicy::ClientAffinity,
    );
    let san = shadow(&svc);
    let c = svc.client(); // affinity 0
    let a = c.alloc(256).unwrap();
    assert_eq!(a.device(), 0);

    // A lane on the wrong member claims it freed the block.
    let err = catch_unwind(AssertUnwindSafe(|| san.on_free(a, 1)))
        .expect_err("cross-device free must panic");
    let msg = panic_message(err);
    assert!(msg.contains("cross-device ownership mismatch"), "{msg}");

    // The record stayed live (the violation fired before any state
    // change), so the real free still balances the books.
    c.free(a).unwrap();
    assert_eq!(san.live_count(), 0);
    drop(c);
    drop(svc);
}

#[test]
fn injected_free_after_migrate_reports() {
    let svc = san_group(
        &[("t2000", Variant::Page), ("t2000", Variant::Page)],
        RoutePolicy::ClientAffinity,
    );
    svc.set_forwarding_grace(Duration::from_secs(60));
    let san = shadow(&svc);
    let c = svc.client();
    let a = c.alloc(512).unwrap();
    let new = svc.migrate(a).expect("migrate");

    // A free reported against the old name *without* the forwarding
    // rewrite — the exact bug class the dispatch hooks exist to catch.
    let err = catch_unwind(AssertUnwindSafe(|| san.on_free(a, a.device())))
        .expect_err("free of a migrated-away name must panic");
    let msg = panic_message(err);
    assert!(msg.contains("migrated-away"), "{msg}");
    assert!(msg.contains("address history"), "{msg}");

    // Balance the real books: the copy is the live generation.
    c.free(new).expect("copy frees under its own name");
    drop(c);
    drop(svc);
}

#[test]
fn leak_at_shutdown_panics_with_report() {
    let svc = san_group(
        &[("t2000", Variant::Page)],
        RoutePolicy::ClientAffinity,
    );
    let c = svc.client();
    let a = c.alloc(2048).unwrap();
    drop(c); // never freed
    let err = catch_unwind(AssertUnwindSafe(move || drop(svc)))
        .expect_err("shutdown with a live block must report a leak");
    let msg = panic_message(err);
    assert!(msg.contains("leaked at service shutdown"), "{msg}");
    assert!(msg.contains("leaked (still live)"), "{msg}");
    assert!(msg.contains(&format!("{a}")), "report names the block: {msg}");
}
