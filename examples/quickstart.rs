//! Quickstart: allocate, write, verify and free device memory through
//! the Ouroboros allocator on the simulated GPU.
//!
//!     cargo run --release --offline --example quickstart
//!
//! Walks the smallest useful surface of the API: build a device + an
//! allocator variant, launch a kernel whose lanes malloc/use/free, and
//! read the run's cost-model statistics.

use std::sync::Arc;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::ouroboros::{
    allocator::{warp_free, warp_malloc},
    build_allocator, HeapConfig, Variant,
};
use ouroboros_tpu::runtime::pattern;
use ouroboros_tpu::simt::{Device, DeviceProfile, Grid};

fn main() {
    // 1. A simulated NVIDIA T2000 running the optimised-CUDA semantics.
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));

    // 2. The paper's fastest variant: the standard page allocator.
    let alloc = build_allocator(Variant::Page, &HeapConfig::default());
    println!(
        "allocator: {} ({} B heap)",
        alloc.variant().label(),
        alloc.heap().cfg.heap_bytes()
    );

    // 3. 256 device threads each allocate 1000 B, write a pattern,
    //    verify it, and free.
    let alloc2 = alloc.clone();
    let stats = device.launch("quickstart", Grid::new(256), move |w| {
        let lanes: Vec<u32> = w.active_lanes().collect();
        let sizes = vec![1000u32; lanes.len()];
        let results = warp_malloc(alloc2.as_ref(), w, &sizes);

        let heap = alloc2.heap();
        let mut addrs = Vec::new();
        for r in &results {
            let addr = r.expect("allocation failed");
            // Write 250 words of a seeded pattern and read them back.
            let base = (addr / 4) as usize;
            for j in 0..250 {
                let v = pattern::expected_word(addr as i32, j, 42);
                heap.write_word(&w.ctx, base + j as usize, v as u32);
            }
            for j in 0..250 {
                let got = heap.read_word(&w.ctx, base + j as usize) as i32;
                assert_eq!(got, pattern::expected_word(addr as i32, j, 42));
            }
            addrs.push(Some(addr));
        }
        for r in warp_free(alloc2.as_ref(), w, &addrs) {
            r.expect("free failed");
        }
    });

    println!("launched {} warps", stats.warps);
    println!("modeled device time: {:.1} us", stats.device_us);
    println!(
        "events: {} atomics, {} mem ops, {} votes",
        stats.events.atomics, stats.events.mem_ops, stats.events.votes
    );
    println!(
        "heap after run: {} live chunks (allocator returned everything)",
        alloc.heap().live_chunks()
    );
    assert!(alloc.debug_consistent());
    println!("quickstart OK");
}
