//! Batch allocation planning through the AOT Pallas kernels.
//!
//!     make artifacts && cargo run --release --offline --example planner_service
//!
//! Demonstrates the L1/L2 planner on the serving path: the coordinator
//! snapshots the live chunk occupancy bitmaps, ships them (plus a batch
//! of request sizes) to the AOT-compiled `plan_alloc` module via PJRT,
//! and gets back size-class bins and first-free page hints — the dense
//! halves of the allocation decision, computed on the accelerator in one
//! vectorised pass (DESIGN.md §4c). The plan is then validated against
//! the live allocator: every hinted page must be genuinely free, and the
//! binning must match the device allocator's own size classes.

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::ouroboros::{build_allocator, params, HeapConfig, Variant};
use ouroboros_tpu::runtime::Runtime;
use ouroboros_tpu::simt::DevCtx;
use ouroboros_tpu::util::errs as anyhow;
use ouroboros_tpu::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let m = rt.manifest.clone();
    println!("PJRT platform: {}", rt.platform());

    // Build a partially loaded allocator so the bitmaps are interesting.
    let alloc = build_allocator(Variant::Chunk, &HeapConfig::default());
    let b = Cuda::new();
    let ctx = DevCtx::new(&b, 1455.0, 0);
    let mut rng = Rng::new(0x97AE);
    let mut live = Vec::new();
    for _ in 0..3000 {
        let size = rng.range(16, 2048) as u32;
        live.push(alloc.malloc(&ctx, size)?);
    }
    // Free a third to punch holes in the bitmaps.
    for i in (0..live.len()).rev().step_by(3) {
        alloc.free(&ctx, live.swap_remove(i))?;
    }

    // Snapshot occupancy for the first PLAN_CHUNKS chunks.
    let heap = alloc.heap();
    let mut bitmaps = vec![0u32; (m.plan_chunks * m.bitmap_words) as usize];
    for c in 0..m.plan_chunks.min(heap.num_chunks()) {
        let snap = heap.header(c).snapshot_bitmap();
        let base = (c * m.bitmap_words) as usize;
        bitmaps[base..base + snap.len()].copy_from_slice(&snap);
    }
    // Unowned chunks present as "full" so the planner skips them.
    for c in 0..m.plan_chunks.min(heap.num_chunks()) {
        if heap.header(c).state() != ouroboros_tpu::ouroboros::chunk::STATE_OWNED {
            let base = (c * m.bitmap_words) as usize;
            bitmaps[base..base + m.bitmap_words as usize].fill(u32::MAX);
        }
    }

    // A batch of incoming request sizes.
    let sizes: Vec<i32> = (0..m.plan_batch)
        .map(|_| rng.range(1, params::CHUNK_SIZE as u64) as i32)
        .collect();

    let t0 = std::time::Instant::now();
    let plan = rt.plan_alloc(&sizes, &bitmaps)?;
    let plan_us = t0.elapsed().as_secs_f64() * 1e6;

    // Validate the plan against the live allocator state.
    let mut binned_ok = 0;
    for (i, &s) in sizes.iter().enumerate() {
        let want = params::queue_for_size(s as u32).unwrap() as i32;
        anyhow::ensure!(
            plan.queue_idx[i] == want,
            "bin mismatch for size {s}: {} != {want}",
            plan.queue_idx[i]
        );
        binned_ok += 1;
    }
    let mut hints = 0;
    let mut hint_checked = 0;
    for c in 0..m.plan_chunks.min(heap.num_chunks()) as usize {
        let ff = plan.first_free[c];
        if ff >= 0 {
            hints += 1;
            let (w, bit) = ((ff / 32) as usize, ff % 32);
            let snap = heap.header(c as u32).snapshot_bitmap();
            // The hinted page was free at snapshot time.
            if (snap[w] >> bit) & 1 == 0 {
                hint_checked += 1;
            }
        }
    }

    println!(
        "plan_alloc: {} sizes binned, {} chunks scanned in {:.0} us on PJRT",
        binned_ok, m.plan_chunks, plan_us
    );
    println!(
        "first-free hints: {hints} chunks with space, {hint_checked} \
         verified free against live bitmaps"
    );
    anyhow::ensure!(hints > 0, "planner found no free chunks");
    println!("planner_service OK");
    Ok(())
}
