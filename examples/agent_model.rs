//! Agent-based model workload — the paper's §1 motivation: "agent based
//! models ... Stopping the kernel, resizing memory allocations and
//! relaunching is simply unfeasible."
//!
//!     cargo run --release --offline --example agent_model
//!
//! Runs a birth/death population model through the **allocation
//! service** (the L3 router + warp-shaped batcher): several simulation
//! worker threads drive agent populations; every birth allocates the
//! agent's state block through the service, every death frees it. The
//! service coalesces the concurrent requests into warp-shaped device
//! batches — the coordinator-side analogue of warp voting (DESIGN §4c).

use std::sync::Arc;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::coordinator::batcher::BatchPolicy;
use ouroboros_tpu::coordinator::service::AllocService;
use ouroboros_tpu::ouroboros::{build_allocator, GlobalAddr, HeapConfig, Variant};
use ouroboros_tpu::simt::{Device, DeviceProfile};
use ouroboros_tpu::util::errs as anyhow;
use ouroboros_tpu::util::rng::Rng;

const WORKERS: usize = 4;
const STEPS: usize = 200;
const INIT_POP: usize = 64;
const BIRTH_P: f64 = 0.30;
const DEATH_P: f64 = 0.28;

fn main() -> anyhow::Result<()> {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    let alloc = build_allocator(Variant::VaChunk, &HeapConfig::default());
    let service = AllocService::start(device, alloc, BatchPolicy::default());

    let totals = std::sync::Mutex::new((0u64, 0u64, 0usize)); // births, deaths, final pop
    std::thread::scope(|s| {
        for wid in 0..WORKERS {
            let client = service.client();
            let totals = &totals;
            s.spawn(move || {
                let mut rng = Rng::new(0xA6E17 + wid as u64);
                // Each agent: its state block's service address.
                let mut agents: Vec<GlobalAddr> = (0..INIT_POP)
                    .map(|_| client.alloc(96).expect("initial agent"))
                    .collect();
                let (mut births, mut deaths) = (0u64, 0u64);
                for _ in 0..STEPS {
                    let mut next = Vec::with_capacity(agents.len() + 8);
                    for addr in agents.drain(..) {
                        if rng.chance(DEATH_P) {
                            client.free(addr).expect("agent death free");
                            deaths += 1;
                        } else {
                            next.push(addr);
                        }
                        if rng.chance(BIRTH_P) {
                            // Newborn state block: 32..512 B.
                            let size = rng.range(32, 512) as u32;
                            next.push(client.alloc(size).expect("birth alloc"));
                            births += 1;
                        }
                    }
                    agents = next;
                }
                // Population teardown through the async ticket pipeline:
                // pipelined waves of 128 frees instead of one blocking
                // round-trip per agent (waves stay well under the lane
                // rings' in-flight capacity even with all workers
                // draining at once).
                let pop = agents.len();
                for wave in agents.chunks(128) {
                    for &addr in wave {
                        client.submit_free(addr).expect("teardown submit");
                    }
                    for (_, done) in client.wait_all() {
                        done.expect("teardown completion")
                            .into_free()
                            .expect("teardown free");
                    }
                }
                let mut t = totals.lock().unwrap();
                t.0 += births;
                t.1 += deaths;
                t.2 += pop;
            });
        }
    });

    let (births, deaths, final_pop) = *totals.lock().unwrap();
    let stats = service.stats();
    println!("agents: {WORKERS} workers x {STEPS} steps");
    println!("births={births} deaths={deaths} final_population={final_pop}");
    println!(
        "service: {} ops in {} batches (mean batch {:.1})",
        stats.ops.load(std::sync::atomic::Ordering::Relaxed),
        stats.batches.load(std::sync::atomic::Ordering::Relaxed),
        stats.mean_batch()
    );
    println!(
        "per-lane batches: {}",
        ouroboros_tpu::coordinator::stats::render_lane_counts(
            &stats.lane_batches()
        )
    );
    anyhow::ensure!(
        stats.allocs.load(std::sync::atomic::Ordering::Relaxed)
            == stats.frees.load(std::sync::atomic::Ordering::Relaxed),
        "alloc/free imbalance"
    );
    let allocator = service.allocator().clone();
    drop(service);
    anyhow::ensure!(allocator.debug_consistent());
    println!("agent_model OK — allocator drained cleanly");
    Ok(())
}
