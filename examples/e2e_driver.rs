//! End-to-end validation driver (DESIGN.md §6): the full three-layer
//! stack on a real small workload.
//!
//!     make artifacts && cargo run --release --offline --example e2e_driver
//!
//! Runs the paper's §3 benchmark driver — 10 iterations of
//! [allocate 1024 × 1000 B → data phase → verify → free] — for **all six
//! allocator variants**, with the data phase executed through the
//! AOT-compiled Pallas `touch_verify` kernel via PJRT (rust loads
//! artifacts/workload_step.hlo.txt; python never runs). Every iteration the
//! rust side independently recomputes checksums and samples the heap to
//! prove the XLA-written data is correct, exactly as the paper's driver
//! "checks that the data is correct when read back".
//!
//! Output: the paper-style mean-all / mean-subsequent table for the CUDA
//! and oneAPI backends. Results are recorded in EXPERIMENTS.md.

use std::sync::Arc;

use ouroboros_tpu::backend::{Cuda, SyclOneapiNv};
use ouroboros_tpu::coordinator::driver::{run_driver, DataPhase, DriverConfig};
use ouroboros_tpu::ouroboros::{HeapConfig, Variant};
use ouroboros_tpu::runtime::Runtime;
use ouroboros_tpu::simt::{Device, DeviceProfile};
use ouroboros_tpu::util::errs as anyhow;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    println!(
        "PJRT platform: {} | artifacts verified against manifest\n",
        rt.platform()
    );

    println!(
        "e2e driver: 10 x [alloc 1024x1000B -> XLA touch_verify -> verify \
         -> free]\n"
    );
    println!(
        "{:<10} {:<10} {:>12} {:>14} {:>10} {:>8}",
        "variant", "backend", "alloc all", "alloc subseq", "free", "verify"
    );
    println!("{}", "-".repeat(70));

    for variant in Variant::all() {
        for (name, dev) in [
            (
                "cuda",
                Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
            ),
            (
                "sycl-nv",
                Device::new(
                    DeviceProfile::t2000(),
                    Arc::new(SyclOneapiNv::new()),
                ),
            ),
        ] {
            let cfg = DriverConfig {
                variant,
                alloc_size: 1000,
                num_allocations: 1024,
                iterations: 10,
                data_phase: DataPhase::Xla,
                heap: HeapConfig::default(),
                seed: 0xE2E,
            };
            let rep = run_driver(&dev, &cfg, Some(&rt))?;
            let a = rep.alloc_split();
            let f = rep.free_split();
            let n = rep.num_allocations as f64;
            println!(
                "{:<10} {:<10} {:>10.3}us {:>12.3}us {:>8.3}us {:>8}",
                variant.id(),
                name,
                a.mean_all / n,
                a.mean_subsequent / n,
                f.mean_subsequent / n,
                if rep.verify_ok() { "OK" } else { "FAIL" }
            );
            anyhow::ensure!(
                rep.verify_ok(),
                "data verification failed for {} on {}",
                variant.id(),
                name
            );
        }
    }
    println!("\ne2e_driver OK — all variants verified through the XLA data phase");
    Ok(())
}
