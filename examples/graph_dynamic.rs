//! Dynamic-graph workload — the paper's §1 motivation: "some
//! applications, such as graph algorithms ... require memory to be
//! dynamically partitioned between the objects of the computation."
//!
//!     cargo run --release --offline --example graph_dynamic
//!
//! Builds a growing graph on the device heap: every vertex owns a device
//! allocation holding its adjacency list; edge inserts grow lists by
//! reallocating into the next size class (alloc-copy-free), so the
//! allocator sees the realloc churn a graph engine generates. Finishes
//! with a BFS over the device-resident adjacency lists and an exact
//! degree-sum check.

use std::sync::Arc;

use ouroboros_tpu::backend::Cuda;
use ouroboros_tpu::ouroboros::{build_allocator, HeapConfig, Variant};
use ouroboros_tpu::simt::{DevCtx, Device, DeviceProfile};
use ouroboros_tpu::util::errs as anyhow;
use ouroboros_tpu::util::rng::Rng;

const NUM_VERTICES: usize = 512;
const NUM_EDGES: usize = 4096;

/// A vertex's adjacency list lives in one device allocation:
/// word 0 = degree, words 1.. = neighbor ids.
struct Vertex {
    addr: u32,
    capacity_words: u32,
}

fn word_base(addr: u32) -> usize {
    (addr / 4) as usize
}

fn main() -> anyhow::Result<()> {
    let device = Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
    // Chunk allocator: the churn-heavy variant with chunk reuse.
    let alloc = build_allocator(Variant::Chunk, &HeapConfig::default());
    let b = Cuda::new();
    let ctx = DevCtx::new(&b, device.profile.clock_mhz, 0);

    // Every vertex starts with a 16 B list (3 neighbor slots).
    let mut vertices: Vec<Vertex> = (0..NUM_VERTICES)
        .map(|_| {
            let addr = alloc.malloc(&ctx, 16).expect("vertex alloc");
            alloc.heap().write_word(&ctx, word_base(addr), 0);
            Vertex { addr, capacity_words: 4 }
        })
        .collect();

    // Insert random edges; grow adjacency lists on demand.
    let mut rng = Rng::new(0x617);
    let mut reallocs = 0u32;
    let mut degree_sum = 0u64;
    for _ in 0..NUM_EDGES {
        let u = rng.below(NUM_VERTICES as u64) as usize;
        let v = rng.below(NUM_VERTICES as u64) as u32;
        let heap = alloc.heap();
        let deg = heap.read_word(&ctx, word_base(vertices[u].addr));
        if deg + 1 >= vertices[u].capacity_words {
            // Grow: allocate double, copy, free the old list.
            let new_words = vertices[u].capacity_words * 2;
            let new_addr = alloc.malloc(&ctx, new_words * 4)?;
            for w in 0..=deg {
                let val = heap.read_word(&ctx, word_base(vertices[u].addr) + w as usize);
                heap.write_word(&ctx, word_base(new_addr) + w as usize, val);
            }
            alloc.free(&ctx, vertices[u].addr)?;
            vertices[u] = Vertex { addr: new_addr, capacity_words: new_words };
            reallocs += 1;
        }
        let base = word_base(vertices[u].addr);
        let deg = heap.read_word(&ctx, base);
        heap.write_word(&ctx, base + 1 + deg as usize, v);
        heap.write_word(&ctx, base, deg + 1);
        degree_sum += 1;
    }

    // BFS from vertex 0 over the device-resident adjacency lists.
    let heap = alloc.heap();
    let mut seen = vec![false; NUM_VERTICES];
    let mut frontier = vec![0usize];
    seen[0] = true;
    let mut reached = 1usize;
    while let Some(u) = frontier.pop() {
        let base = word_base(vertices[u].addr);
        let deg = heap.read_word(&ctx, base);
        for i in 0..deg {
            let v = heap.read_word(&ctx, base + 1 + i as usize) as usize;
            if v < NUM_VERTICES && !seen[v] {
                seen[v] = true;
                reached += 1;
                frontier.push(v);
            }
        }
    }

    // Exact degree-sum check: everything written is still readable.
    let total: u64 = vertices
        .iter()
        .map(|v| heap.read_word(&ctx, word_base(v.addr)) as u64)
        .sum();
    anyhow::ensure!(total == degree_sum, "degree sum mismatch");

    println!("graph: {NUM_VERTICES} vertices, {NUM_EDGES} edges");
    println!("adjacency reallocs (grow alloc-copy-free): {reallocs}");
    println!("BFS from v0 reached {reached} vertices");
    println!("live heap chunks: {}", alloc.heap().live_chunks());

    // Tear down: free every list; the heap must drain to zero after a
    // sweep (the self-eating property).
    for v in &vertices {
        alloc.free(&ctx, v.addr)?;
    }
    let reclaimed = alloc.sweep(&ctx);
    println!("teardown: sweep reclaimed {reclaimed} chunks");
    anyhow::ensure!(alloc.heap().live_chunks() == 0, "heap leak");
    anyhow::ensure!(alloc.debug_consistent());
    println!("graph_dynamic OK");
    Ok(())
}
