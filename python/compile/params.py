"""Shared geometry for the Ouroboros-TPU reproduction.

Single source of truth for the allocator size-class geometry and the AOT
artifact shapes.  `aot.py` serialises these into `artifacts/manifest.txt`
so the rust coordinator (rust/src/runtime/artifact.rs) never hardcodes
them independently.

The geometry follows Ouroboros defaults (Winter et al., ICS'20), which the
paper under reproduction inherits: an 8 KiB chunk, smallest page 16 B, and
one queue per power-of-two page size.
"""

# ---------------------------------------------------------------------------
# Allocator geometry (mirrors rust/src/ouroboros/params.rs)
# ---------------------------------------------------------------------------

SMALLEST_PAGE = 16              # bytes; queue 0 page size
NUM_QUEUES = 10                 # page sizes 16 B .. 8 KiB
CHUNK_SIZE = SMALLEST_PAGE << (NUM_QUEUES - 1)   # 8192 B
PAGE_SIZES = [SMALLEST_PAGE << i for i in range(NUM_QUEUES)]
MAX_PAGES_PER_CHUNK = CHUNK_SIZE // SMALLEST_PAGE  # 512
BITMAP_WORDS = MAX_PAGES_PER_CHUNK // 32           # 16 u32 words / chunk

# ---------------------------------------------------------------------------
# AOT artifact shapes (static: XLA executables are shape-specialised)
# ---------------------------------------------------------------------------

# plan_alloc: batched allocation planning
PLAN_BATCH = 1024               # allocation requests per planner call
PLAN_CHUNKS = 2048              # chunk bitmaps scanned per planner call

# workload_step: the paper driver's data phase (write pattern + checksum)
TOUCH_PAGES = 1024              # pages touched per call
PAGE_WORDS = 256                # i32 words materialised per page (1 KiB)

# ---------------------------------------------------------------------------
# Pattern constants for touch_verify (Fibonacci/Murmur-style odd mixers).
# Kept as *python ints* of the u32 bit pattern; both sides reinterpret as
# two's-complement i32 and rely on wrapping arithmetic.
# ---------------------------------------------------------------------------

MIX_A = 0x9E3779B1              # golden-ratio odd constant
MIX_B = 0x85EBCA77              # murmur3 fmix constant

# Pallas block tiles (VMEM sizing rationale in DESIGN.md §8)
SIZE_TILE = 256                 # size_to_queue: requests per tile
BM_TILE = 256                   # bitmap_scan: chunks per tile
TOUCH_TILE = 256                # touch_verify: pages per tile


def manifest_entries():
    """Key/value pairs serialised to artifacts/manifest.txt."""
    return {
        "smallest_page": SMALLEST_PAGE,
        "num_queues": NUM_QUEUES,
        "chunk_size": CHUNK_SIZE,
        "max_pages_per_chunk": MAX_PAGES_PER_CHUNK,
        "bitmap_words": BITMAP_WORDS,
        "plan_batch": PLAN_BATCH,
        "plan_chunks": PLAN_CHUNKS,
        "touch_pages": TOUCH_PAGES,
        "page_words": PAGE_WORDS,
        "mix_a": MIX_A,
        "mix_b": MIX_B,
    }
