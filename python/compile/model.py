"""L2: the jax compute graphs the rust coordinator executes through PJRT.

Two entry points, both lowered once by aot.py to HLO text:

* ``workload_step`` — the paper driver's data phase: write a seeded pattern
  into every page allocated this round and checksum it for read-back
  verification (Figure-driver §3 Methods: "allocating memory, writing some
  data, checking that the data is correct when read back").

* ``plan_alloc`` — the batch allocation planner: size->queue binning fused
  with the occupancy-bitmap first-free scan, used by the rust alloc service
  to pre-plan page selection for warp-shaped request batches (the TPU
  analogue of the warp-vote cooperation the paper struggles to express in
  SYCL — DESIGN.md §4c).

Both call the L1 Pallas kernels so the kernels lower into the same HLO
module; nothing here runs at serving time.
"""

import jax.numpy as jnp

from . import params
from .kernels import bitmap_scan, frag_metric, size_to_queue, touch_verify


def workload_step(offsets, seed):
    """Data phase over one batch of touched pages.

    offsets: i32[TOUCH_PAGES] page offsets (unique per live allocation)
    seed:    i32[1] per-iteration seed
    returns  (buf i32[P, PAGE_WORDS], checksum i32[P], probe i32[P])
    """
    return touch_verify(offsets, seed)


def plan_alloc(sizes, bitmaps):
    """Batched allocation planning.

    sizes:   i32[PLAN_BATCH] request sizes in bytes
    bitmaps: u32[PLAN_CHUNKS, BITMAP_WORDS] chunk occupancy masks
    returns  (queue_idx i32[N], first_free i32[C], free_count i32[C])
    """
    q = size_to_queue(sizes)
    first, count = bitmap_scan(bitmaps)
    return q, first, count


def frag_report(bitmaps):
    """Per-chunk fragmentation metrics for the coordinator's §4.1 study.

    bitmaps: u32[PLAN_CHUNKS, BITMAP_WORDS]
    returns  (free_count i32[C], longest_run i32[C], frag_score i32[C])
    """
    return frag_metric(bitmaps)


def example_args():
    """Shape-only example arguments for AOT lowering."""
    import jax

    return {
        "workload_step": (
            jax.ShapeDtypeStruct((params.TOUCH_PAGES,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ),
        "plan_alloc": (
            jax.ShapeDtypeStruct((params.PLAN_BATCH,), jnp.int32),
            jax.ShapeDtypeStruct(
                (params.PLAN_CHUNKS, params.BITMAP_WORDS), jnp.uint32
            ),
        ),
        "frag_report": (
            jax.ShapeDtypeStruct(
                (params.PLAN_CHUNKS, params.BITMAP_WORDS), jnp.uint32
            ),
        ),
    }
