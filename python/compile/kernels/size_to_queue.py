"""L1 Pallas kernel: branchless size -> queue-index binning.

The GPU original computes, per allocating thread, the index of the
size-class queue that serves its request (ceil-log2 of the request size
relative to the smallest page).  Here the binning is done for a whole batch
of requests in one vectorised pass: instead of per-lane CLZ bit tricks, the
queue index is the *count of page sizes strictly smaller than the request*,
which is a sum of NUM_QUEUES-1 broadcast comparisons — branchless and exact
on the VPU.

Tiling: 1-D grid over the request batch, SIZE_TILE requests per block
(SIZE_TILE * 4 B = 1 KiB per tile in VMEM; trivially double-buffered).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import params


def _kernel(sizes_ref, out_ref):
    s = sizes_ref[...].astype(jnp.int32)
    q = jnp.zeros_like(s)
    # Unrolled at trace time: NUM_QUEUES-1 compares + adds, no branches.
    for ps in params.PAGE_SIZES[:-1]:
        q = q + (s > ps).astype(jnp.int32)
    out_ref[...] = jnp.minimum(q, params.NUM_QUEUES - 1)


@functools.partial(jax.jit, static_argnames=("tile",))
def size_to_queue(sizes, tile=params.SIZE_TILE):
    """sizes: i32[N] -> i32[N]; N must be a multiple of ``tile``."""
    (n,) = sizes.shape
    assert n % tile == 0, f"batch {n} not a multiple of tile {tile}"
    return pl.pallas_call(
        _kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(sizes.astype(jnp.int32))
