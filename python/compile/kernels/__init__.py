# L1: Pallas kernels for the compute hot-spots (interpret=True;
# real-TPU lowering would emit Mosaic custom-calls the CPU PJRT plugin
# cannot execute -- see DESIGN.md section 4).
from .size_to_queue import size_to_queue
from .bitmap_scan import bitmap_scan
from .frag_metric import frag_metric
from .touch_verify import touch_verify

__all__ = ["size_to_queue", "bitmap_scan", "frag_metric", "touch_verify"]
