"""L1 Pallas kernel: the benchmark driver's data phase.

The paper's driver (its §3 Methods) allocates, *writes some data, checks
that the data is correct when read back*, and frees.  On the GPU each
thread writes its own allocation; here the whole batch of touched pages is
materialised as one (pages, PAGE_WORDS) i32 tile pass: a mixed pattern
derived from (page offset, word index, seed) is written, and a wrapping-i32
checksum per page is reduced in the same pass, so the rust side can verify
read-back correctness without re-streaming the buffer.

Tiling: (TOUCH_TILE, PAGE_WORDS) i32 blocks = 256x256x4 B = 256 KiB in
VMEM per buffer; with in/out + double buffering this stays ~1 MiB, well
inside VMEM.  Integer multiply-add + row reduction run on the VPU (the MXU
has no role in this integer workload).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import params


def _kernel(off_ref, seed_ref, buf_ref, sum_ref, probe_ref):
    off = off_ref[...].astype(jnp.int32)                     # (tile,)
    seed = seed_ref[0].astype(jnp.int32)
    mix_a = jnp.uint32(params.MIX_A).astype(jnp.int32)
    mix_b = jnp.uint32(params.MIX_B).astype(jnp.int32)
    j = jnp.arange(buf_ref.shape[1], dtype=jnp.int32)
    base = (off * mix_a) ^ seed
    val = base[:, None] + j[None, :] * mix_b                 # (tile, W)
    buf_ref[...] = val
    sum_ref[...] = jnp.sum(val, axis=1, dtype=jnp.int32)
    probe_ref[...] = val[:, 0]


@functools.partial(jax.jit, static_argnames=("tile", "page_words"))
def touch_verify(offsets, seed, tile=params.TOUCH_TILE,
                 page_words=params.PAGE_WORDS):
    """offsets: i32[P], seed: i32[1]
    -> (buf i32[P, page_words], checksum i32[P], probe i32[P])."""
    (p,) = offsets.shape
    assert p % tile == 0, f"page count {p} not a multiple of tile {tile}"
    return pl.pallas_call(
        _kernel,
        grid=(p // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((tile, page_words), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((p, page_words), jnp.int32),
            jax.ShapeDtypeStruct((p,), jnp.int32),
            jax.ShapeDtypeStruct((p,), jnp.int32),
        ),
        interpret=True,
    )(offsets.astype(jnp.int32), seed.astype(jnp.int32))
