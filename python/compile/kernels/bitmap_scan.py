"""L1 Pallas kernel: batched find-first-free over chunk occupancy bitmaps.

The chunk-based Ouroboros allocators keep a MAX_PAGES_PER_CHUNK-bit
occupancy mask in each chunk header and find a free page with repeated
atomic bit scans.  The GPU code does a per-thread ffs over the words; here
a whole tile of chunks is scanned in one vectorised pass: the bitmap tile
is expanded to (tile, words, 32) lanes, free lanes keep their global bit
index, occupied lanes a sentinel, and a min-reduction yields the first free
page per chunk.  free-page *counts* come from the same expansion.

This is the "batch allocation planner" the rust coordinator calls through
PJRT to pre-plan page selection for a warp-shaped batch of requests
(DESIGN.md §4c).

Tiling: (BM_TILE, BITMAP_WORDS) u32 blocks = 256x16x4 B = 16 KiB in VMEM;
the (tile, words, 32) expansion is 512 KiB of transient VPU registers /
VMEM scratch, well under the ~16 MiB budget with double-buffering headroom.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import params


def _kernel(bm_ref, first_ref, count_ref):
    # Word-level formulation (perf pass, EXPERIMENTS.md §Perf L1): the
    # original expanded every word to 32 bit lanes — a (tile, W, 32)
    # intermediate and ~32x the VPU work. Instead:
    #   * free count per word  = 32 - popcount(word)
    #   * first zero bit       = popcount(t - 1), t = ~word & (word + 1)
    #     (t isolates the lowest zero bit; t-1 masks the bits below it;
    #     full words give t == 0 -> ffz = 32, naturally out of range)
    # Everything stays (tile, W): ~5x fewer flops, 32x smaller transient.
    bm = bm_ref[...].astype(jnp.uint32)          # (tile, W)
    tile, w = bm.shape
    pop = jax.lax.population_count(bm).astype(jnp.int32)
    count_ref[...] = jnp.sum(32 - pop, axis=1, dtype=jnp.int32)

    t = (~bm) & (bm + jnp.uint32(1))
    ffz = jax.lax.population_count(t - jnp.uint32(1)).astype(jnp.int32)
    ffz = jnp.where(t == 0, jnp.int32(32), ffz)  # word full
    base = (32 * jnp.arange(w, dtype=jnp.int32))[None, :]
    sentinel = jnp.int32(w * 32)
    idx = jnp.where(ffz < 32, base + ffz, sentinel)
    first = jnp.min(idx, axis=1).astype(jnp.int32)
    first_ref[...] = jnp.where(first == sentinel, jnp.int32(-1), first)


@functools.partial(jax.jit, static_argnames=("tile",))
def bitmap_scan(bitmaps, tile=params.BM_TILE):
    """bitmaps: u32[C, W] -> (first_free i32[C], free_count i32[C]).

    C must be a multiple of ``tile``; W is static (BITMAP_WORDS for the
    production artifact, but any W works — tests sweep it).
    """
    c, w = bitmaps.shape
    assert c % tile == 0, f"chunk count {c} not a multiple of tile {tile}"
    return pl.pallas_call(
        _kernel,
        grid=(c // tile,),
        in_specs=[pl.BlockSpec((tile, w), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((c,), jnp.int32),
            jax.ShapeDtypeStruct((c,), jnp.int32),
        ),
        interpret=True,
    )(bitmaps.astype(jnp.uint32))
