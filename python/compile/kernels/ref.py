"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must agree exactly (integer kernels — bit-for-bit) with the corresponding
function here, across the shape/dtype sweeps in python/tests/.
"""

import jax.numpy as jnp

from .. import params


def size_to_queue(sizes):
    """Queue index for each request size.

    A request of ``s`` bytes is served from the smallest power-of-two page
    that fits it: queue ``i`` serves pages of ``SMALLEST_PAGE << i`` bytes.
    Sizes above the largest page clamp to the last queue (the rust
    coordinator rejects them before they ever reach the planner; the clamp
    only fixes the kernel's total function).

    sizes: i32[N] -> i32[N] in [0, NUM_QUEUES)
    """
    sizes = sizes.astype(jnp.int32)
    q = jnp.zeros_like(sizes)
    for ps in params.PAGE_SIZES[:-1]:
        q = q + (sizes > ps).astype(jnp.int32)
    return jnp.minimum(q, params.NUM_QUEUES - 1)


def bitmap_scan(bitmaps):
    """First-free-page scan over chunk occupancy bitmaps.

    Bit ``p`` of word ``w`` of row ``c`` is 1 iff page ``w*32 + p`` of chunk
    ``c`` is allocated.  Callers mark out-of-range bits (chunks whose queue
    has fewer than MAX_PAGES_PER_CHUNK pages) as 1/occupied so the scan
    needs no per-row page count.

    bitmaps: u32[C, W] -> (first_free: i32[C] (-1 if full),
                           free_count: i32[C])
    """
    bitmaps = bitmaps.astype(jnp.uint32)
    c, w = bitmaps.shape
    bits = jnp.arange(32, dtype=jnp.uint32)
    lanes = (bitmaps[:, :, None] >> bits[None, None, :]) & jnp.uint32(1)
    free = lanes == 0
    pos = jnp.arange(w * 32, dtype=jnp.int32).reshape(1, w, 32)
    sentinel = jnp.int32(w * 32)
    idx = jnp.where(free, pos, sentinel)
    first = jnp.min(idx, axis=(1, 2)).astype(jnp.int32)
    first = jnp.where(first == sentinel, jnp.int32(-1), first)
    count = jnp.sum(free, axis=(1, 2)).astype(jnp.int32)
    return first, count


def frag_metric(bitmaps):
    """Per-chunk fragmentation metrics (bit-level python model).

    bitmaps: u32[C, W] -> (free_count i32[C], longest_run i32[C],
    frag_score i32[C] in permille)
    """
    import numpy as np

    bm = np.asarray(bitmaps, dtype=np.uint32)
    c, w = bm.shape
    free_count = np.zeros(c, np.int32)
    longest = np.zeros(c, np.int32)
    score = np.zeros(c, np.int32)
    for r in range(c):
        bits = [(int(bm[r, j]) >> b) & 1 for j in range(w) for b in range(32)]
        free = [1 - x for x in bits]
        free_count[r] = sum(free)
        run = best = 0
        for f in free:
            run = run + 1 if f else 0
            best = max(best, run)
        longest[r] = best
        score[r] = 0 if free_count[r] == 0 else 1000 - (1000 * best) // int(free_count[r])
    return (jnp.asarray(free_count), jnp.asarray(longest),
            jnp.asarray(score))


def touch_verify(offsets, seed):
    """The paper driver's data phase: write a seeded pattern into each
    allocated page, and checksum it for read-back verification.

    The pattern is a deterministic function of (page offset, word index,
    seed) so the rust side can independently recompute any word and the
    checksum: val[p, j] = (off[p] * MIX_A ^ seed) + j * MIX_B, all in
    wrapping i32 arithmetic.

    offsets: i32[P], seed: i32[1]
      -> (buf: i32[P, PAGE_WORDS], checksum: i32[P], probe: i32[P])
    """
    offsets = offsets.astype(jnp.int32)
    mix_a = jnp.uint32(params.MIX_A).astype(jnp.int32)
    mix_b = jnp.uint32(params.MIX_B).astype(jnp.int32)
    j = jnp.arange(params.PAGE_WORDS, dtype=jnp.int32)
    base = (offsets * mix_a) ^ seed[0].astype(jnp.int32)
    buf = base[:, None] + j[None, :] * mix_b
    checksum = jnp.sum(buf, axis=1, dtype=jnp.int32)
    probe = buf[:, 0]
    return buf, checksum, probe
