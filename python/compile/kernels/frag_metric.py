"""L1 Pallas kernel: per-chunk fragmentation metrics.

Feeds the coordinator's fragmentation study (paper §4.1: the page
allocator "suffers more from fragmentation"): for each chunk occupancy
bitmap, compute the free-page count, the longest *contiguous* free run,
and a fragmentation score in permille:

    score = 1000 * (1 - longest_run / free_count)      (0 when empty)

A chunk whose free pages are all contiguous scores 0; maximally
scattered free pages approach 1000.

The longest-run computation is exact and fully vectorised: with bit
lanes expanded to (tile, W*32), the run length ending at position i is
``pos_i - cummax(pos_j * occupied_j)`` — one `lax.cummax` along the page
axis instead of a 512-step loop.

Tiling: (BM_TILE, W) u32 blocks; the (tile, W*32) i32 expansion is
256x512x4 B = 512 KiB of VMEM scratch — comfortably resident.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import params


def _kernel(bm_ref, free_ref, run_ref, score_ref):
    bm = bm_ref[...].astype(jnp.uint32)                   # (tile, W)
    tile, w = bm.shape
    bits = jnp.arange(32, dtype=jnp.uint32)
    lanes = (bm[:, :, None] >> bits[None, None, :]) & jnp.uint32(1)
    occupied = lanes.reshape(tile, w * 32).astype(jnp.int32)  # 1 = taken
    free = 1 - occupied

    free_count = jnp.sum(free, axis=1, dtype=jnp.int32)

    # pos 1..N; run ending at i = pos_i - max_{j<=i}(pos_j * occupied_j).
    pos = jnp.arange(1, w * 32 + 1, dtype=jnp.int32)[None, :]
    barrier = jax.lax.cummax(pos * occupied, axis=1)
    runs = (pos - barrier) * free
    longest = jnp.max(runs, axis=1).astype(jnp.int32)

    score = jnp.where(
        free_count > 0,
        1000 - (1000 * longest) // jnp.maximum(free_count, 1),
        jnp.int32(0),
    )
    free_ref[...] = free_count
    run_ref[...] = longest
    score_ref[...] = score


@functools.partial(jax.jit, static_argnames=("tile",))
def frag_metric(bitmaps, tile=params.BM_TILE):
    """bitmaps: u32[C, W] -> (free_count i32[C], longest_run i32[C],
    frag_score i32[C])."""
    c, w = bitmaps.shape
    assert c % tile == 0, f"chunk count {c} not a multiple of tile {tile}"
    return pl.pallas_call(
        _kernel,
        grid=(c // tile,),
        in_specs=[pl.BlockSpec((tile, w), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((c,), jnp.int32),
            jax.ShapeDtypeStruct((c,), jnp.int32),
            jax.ShapeDtypeStruct((c,), jnp.int32),
        ),
        interpret=True,
    )(bitmaps.astype(jnp.uint32))
