"""L1/L2 performance report: XLA cost analysis of the lowered modules +
VMEM footprint estimates from the BlockSpecs.

interpret=True gives CPU-numpy timings only (not a TPU proxy), so the
optimization signal is structural: FLOPs / bytes accessed / output bytes
from XLA's cost model, plus the per-tile VMEM budget. Records land in
EXPERIMENTS.md §Perf.

Usage: cd python && python -m compile.perf_report
"""

import jax

from . import model, params


def cost_analysis(fn, *args):
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, list):
        c = c[0]
    return c or {}


def vmem_table():
    """Per-kernel VMEM tile budgets (bytes), from the BlockSpecs."""
    rows = []
    rows.append(("size_to_queue",
                 params.SIZE_TILE * 4,          # in: sizes tile
                 params.SIZE_TILE * 4))          # out: queue idx tile
    rows.append(("bitmap_scan",
                 params.BM_TILE * params.BITMAP_WORDS * 4,
                 2 * params.BM_TILE * 4))
    rows.append(("touch_verify",
                 (params.TOUCH_TILE + 1) * 4,
                 params.TOUCH_TILE * (params.PAGE_WORDS + 2) * 4))
    rows.append(("frag_metric",
                 params.BM_TILE * params.BITMAP_WORDS * 4,
                 3 * params.BM_TILE * 4))
    return rows


def main():
    args = model.example_args()
    print("== XLA cost analysis (lowered+compiled modules) ==")
    for name, fn in [
        ("workload_step", model.workload_step),
        ("plan_alloc", model.plan_alloc),
        ("frag_report", model.frag_report),
    ]:
        c = cost_analysis(fn, *args[name])
        flops = c.get("flops", float("nan"))
        bytes_out = c.get("bytes accessed output {}", c.get("bytes accessed", float("nan")))
        print(f"{name:>14}: flops={flops:>12.0f} bytes_accessed="
              f"{c.get('bytes accessed', float('nan')):>12.0f} "
              f"utilization_keys={sorted(k for k in c if 'utilization' in k)[:3]}")
        _ = bytes_out

    print("\n== VMEM tile budgets (double-buffered estimate = 2x) ==")
    for name, in_b, out_b in vmem_table():
        tot = in_b + out_b
        print(f"{name:>14}: in={in_b:>8} B out={out_b:>8} B "
              f"tile_total={tot:>8} B (2x buffered {2 * tot:>8} B; "
              f"VMEM budget ~16 MiB)")


if __name__ == "__main__":
    main()
