"""touch_verify Pallas kernel vs pure-jnp oracle and an independent
numpy wrapping-i32 model (the same model rust re-implements)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import params
from compile.kernels import ref
from compile.kernels.touch_verify import touch_verify


def _np_model(offsets, seed, page_words):
    """Independent wrapping-int32 model (mirrors rust pattern::expected_*)."""
    off = np.asarray(offsets, np.int64)
    mix_a = np.int64(np.int32(np.uint32(params.MIX_A)))
    mix_b = np.int64(np.int32(np.uint32(params.MIX_B)))
    j = np.arange(page_words, dtype=np.int64)
    base = np.int32((off * mix_a) & 0xFFFFFFFF).astype(np.int64)
    base = np.int64(np.int32(base ^ np.int64(seed)))
    buf = np.int32((base[:, None] + j[None, :] * mix_b) & 0xFFFFFFFF)
    checksum = np.int32(buf.astype(np.int64).sum(axis=1) & 0xFFFFFFFF)
    return buf, checksum, buf[:, 0]


def _run(offsets, seed, tile=8, page_words=16):
    off = jnp.asarray(offsets, jnp.int32)
    sd = jnp.asarray([seed], jnp.int32)
    buf, cks, probe = touch_verify(off, sd, tile=tile, page_words=page_words)
    br, cr, pr = ref.touch_verify(off, sd)
    # oracle uses params.PAGE_WORDS; compare against the matching slice model
    nb, nc, npr = _np_model(offsets, seed, page_words)
    np.testing.assert_array_equal(np.asarray(buf), nb)
    np.testing.assert_array_equal(np.asarray(cks), nc)
    np.testing.assert_array_equal(np.asarray(probe), npr)
    return np.asarray(buf), np.asarray(cks)


class TestPattern:
    def test_distinct_offsets_distinct_pages(self):
        buf, _ = _run(list(range(8)), seed=1)
        assert len({tuple(r) for r in buf.tolist()}) == 8

    def test_seed_changes_pattern(self):
        b1, _ = _run(list(range(8)), seed=1)
        b2, _ = _run(list(range(8)), seed=2)
        assert (b1 != b2).any()

    def test_checksum_is_row_sum_wrapping(self):
        buf, cks = _run([0, 1, 2, 3, 4, 5, 6, 7], seed=7)
        want = buf.astype(np.int64).sum(axis=1)
        want = ((want + 2**31) % 2**32 - 2**31).astype(np.int32)
        np.testing.assert_array_equal(cks, want)

    def test_production_shape_against_oracle(self):
        rng = np.random.default_rng(2)
        off = rng.integers(0, 2**20, params.TOUCH_PAGES).astype(np.int32)
        sd = jnp.asarray([12345], jnp.int32)
        got = touch_verify(jnp.asarray(off), sd)
        want = ref.touch_verify(jnp.asarray(off), sd)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


class TestProperties:
    @given(st.lists(st.integers(min_value=-2**31, max_value=2**31 - 1),
                    min_size=8, max_size=8),
           st.integers(min_value=-2**31, max_value=2**31 - 1))
    def test_matches_independent_model(self, offsets, seed):
        _run(offsets, seed)

    @given(st.integers(min_value=0, max_value=2**20),
           st.sampled_from([8, 16, 64, 256]))
    def test_page_words_sweep(self, off0, page_words):
        _run([off0 + i for i in range(8)], seed=99, page_words=page_words)
