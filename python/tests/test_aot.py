"""AOT pipeline: file outputs, manifest format, and HLO parseability."""

import os

from compile import aot, params


def test_main_writes_all_artifacts(tmp_path):
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    for name in ["workload_step", "plan_alloc", "frag_report",
                 "touch_verify"]:
        p = tmp_path / f"{name}.hlo.txt"
        assert p.exists(), name
        text = p.read_text()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
    # Alias is byte-identical to the data-phase module.
    assert (tmp_path / "touch_verify.hlo.txt").read_text() == (
        tmp_path / "workload_step.hlo.txt"
    ).read_text()


def test_manifest_format(tmp_path):
    aot.write_manifest(os.path.join(tmp_path, "manifest.txt"))
    lines = (tmp_path / "manifest.txt").read_text().splitlines()
    kv = dict(
        line.split("=", 1) for line in lines if line and not line.startswith("#")
    )
    for key, val in params.manifest_entries().items():
        assert kv[key] == str(val), key


def test_hlo_text_has_no_64bit_id_serialization():
    # The interchange contract: we ship text, never serialized protos
    # (xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction ids).
    import jax

    from compile import model

    args = model.example_args()["plan_alloc"]
    text = aot.to_hlo_text(jax.jit(model.plan_alloc).lower(*args))
    assert text.startswith("HloModule")
    # Entry computation present with the expected parameter shapes.
    assert "s32[1024]" in text and "u32[2048,16]" in text
