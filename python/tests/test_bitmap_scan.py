"""bitmap_scan Pallas kernel vs pure-jnp oracle (bit-exact)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import params
from compile.kernels import ref
from compile.kernels.bitmap_scan import bitmap_scan


def _run(bm, tile):
    bm = jnp.asarray(bm, dtype=jnp.uint32)
    first, count = bitmap_scan(bm, tile=tile)
    fr, cr = ref.bitmap_scan(bm)
    np.testing.assert_array_equal(np.asarray(first), np.asarray(fr))
    np.testing.assert_array_equal(np.asarray(count), np.asarray(cr))
    return np.asarray(first), np.asarray(count)


class TestEdges:
    def test_empty_bitmap_first_bit_zero(self):
        first, count = _run(np.zeros((8, 4), np.uint32), tile=8)
        assert (first == 0).all()
        assert (count == 128).all()

    def test_full_bitmap_reports_minus_one(self):
        first, count = _run(np.full((8, 4), 0xFFFFFFFF, np.uint32), tile=8)
        assert (first == -1).all()
        assert (count == 0).all()

    @pytest.mark.parametrize("bit", [0, 1, 31, 32, 33, 63, 64, 127])
    def test_single_free_bit(self, bit):
        bm = np.full((8, 4), 0xFFFFFFFF, np.uint32)
        w, b = divmod(bit, 32)
        bm[:, w] &= np.uint32(0xFFFFFFFF) ^ np.uint32(1 << b)
        first, count = _run(bm, tile=8)
        assert (first == bit).all()
        assert (count == 1).all()

    def test_first_free_is_lowest_index(self):
        bm = np.zeros((8, 4), np.uint32)
        bm[:, 0] = 0b111  # pages 0..2 taken
        first, _ = _run(bm, tile=8)
        assert (first == 3).all()

    def test_production_shape(self):
        rng = np.random.default_rng(1)
        bm = rng.integers(0, 2**32, (params.PLAN_CHUNKS, params.BITMAP_WORDS),
                          dtype=np.uint64).astype(np.uint32)
        _run(bm, tile=params.BM_TILE)


class TestProperties:
    @given(st.integers(min_value=0, max_value=2**32 - 1),
           st.integers(min_value=1, max_value=params.BITMAP_WORDS))
    def test_uniform_word_matches_oracle(self, word, w):
        bm = np.full((8, w), word, np.uint32)
        _run(bm, tile=8)

    @given(st.lists(st.integers(min_value=0, max_value=2**32 - 1),
                    min_size=16, max_size=16))
    def test_random_rows_match_oracle(self, words):
        bm = np.array(words, np.uint32).reshape(4, 4)
        bm = np.vstack([bm, bm])  # tile-divisible 8 rows
        first, count = _run(bm, tile=8)
        # Cross-check against a bit-level python model.
        for r in range(8):
            bits = [(int(bm[r, w]) >> b) & 1 for w in range(4) for b in range(32)]
            want_first = bits.index(0) if 0 in bits else -1
            assert first[r] == want_first
            assert count[r] == bits.count(0)

    @given(st.integers(min_value=0, max_value=127))
    def test_count_plus_popcount_is_total(self, seed):
        rng = np.random.default_rng(seed)
        bm = rng.integers(0, 2**32, (8, 4), dtype=np.uint64).astype(np.uint32)
        _, count = _run(bm, tile=8)
        pop = np.array([bin(int(x)).count("1") for x in bm.reshape(-1)])
        pop = pop.reshape(8, 4).sum(axis=1)
        assert ((count + pop) == 128).all()
