import os
import sys

# Tests run as `cd python && python -m pytest tests/`; make the `compile`
# package importable regardless of pytest's rootdir heuristics.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from hypothesis import settings

# Single-core CI box + interpret-mode Pallas: keep example counts modest and
# disable the wall-clock deadline (first call pays jit tracing).
settings.register_profile("repro", max_examples=25, deadline=None)
settings.load_profile("repro")
