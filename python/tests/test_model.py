"""L2 model composition + AOT lowering sanity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model, params
from compile.kernels import ref


class TestPlanAlloc:
    def test_composes_kernels(self):
        rng = np.random.default_rng(3)
        sizes = jnp.asarray(
            rng.integers(1, params.CHUNK_SIZE, params.PLAN_BATCH), jnp.int32)
        bm = jnp.asarray(
            rng.integers(0, 2**32, (params.PLAN_CHUNKS, params.BITMAP_WORDS),
                         dtype=np.uint64).astype(np.uint32))
        q, first, count = model.plan_alloc(sizes, bm)
        np.testing.assert_array_equal(np.asarray(q),
                                      np.asarray(ref.size_to_queue(sizes)))
        fr, cr = ref.bitmap_scan(bm)
        np.testing.assert_array_equal(np.asarray(first), np.asarray(fr))
        np.testing.assert_array_equal(np.asarray(count), np.asarray(cr))

    def test_planned_page_is_actually_free(self):
        rng = np.random.default_rng(4)
        bm = rng.integers(0, 2**32, (params.PLAN_CHUNKS, params.BITMAP_WORDS),
                          dtype=np.uint64).astype(np.uint32)
        sizes = jnp.ones(params.PLAN_BATCH, jnp.int32)
        _, first, _ = model.plan_alloc(sizes, jnp.asarray(bm))
        first = np.asarray(first)
        for c in np.nonzero(first >= 0)[0][:64]:
            w, b = divmod(int(first[c]), 32)
            assert (int(bm[c, w]) >> b) & 1 == 0


class TestAot:
    def test_workload_step_lowers_to_hlo_text(self):
        args = model.example_args()["workload_step"]
        text = aot.to_hlo_text(jax.jit(model.workload_step).lower(*args))
        assert text.startswith("HloModule")
        assert "s32[1024,256]" in text  # buf output shape present

    def test_plan_alloc_lowers_to_hlo_text(self):
        args = model.example_args()["plan_alloc"]
        text = aot.to_hlo_text(jax.jit(model.plan_alloc).lower(*args))
        assert text.startswith("HloModule")
        assert "u32[2048,16]" in text  # bitmap input shape present

    def test_manifest_matches_params(self):
        ent = params.manifest_entries()
        assert ent["chunk_size"] == params.SMALLEST_PAGE << (params.NUM_QUEUES - 1)
        assert ent["bitmap_words"] * 32 == ent["max_pages_per_chunk"]
        assert ent["mix_a"] % 2 == 1 and ent["mix_b"] % 2 == 1
