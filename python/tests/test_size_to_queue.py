"""size_to_queue Pallas kernel vs pure-jnp oracle (bit-exact)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import params
from compile.kernels import ref
from compile.kernels.size_to_queue import size_to_queue


def _run(sizes, tile):
    s = jnp.asarray(sizes, dtype=jnp.int32)
    got = np.asarray(size_to_queue(s, tile=tile))
    want = np.asarray(ref.size_to_queue(s))
    np.testing.assert_array_equal(got, want)
    return got


class TestBoundaries:
    def test_exact_page_sizes_map_to_their_queue(self):
        # A request of exactly PAGE_SIZES[i] bytes fits queue i.
        sizes = params.PAGE_SIZES + [0] * (16 - params.NUM_QUEUES)
        got = _run(sizes, tile=16)
        for i in range(params.NUM_QUEUES):
            assert got[i] == i

    def test_one_over_page_size_moves_up(self):
        sizes = [ps + 1 for ps in params.PAGE_SIZES[:-1]] + [0] * 7
        got = _run(sizes, tile=16)
        for i in range(params.NUM_QUEUES - 1):
            assert got[i] == i + 1

    def test_tiny_sizes_queue_zero(self):
        got = _run([1, 2, 3, 15, 16, 0, -1, -100], tile=8)
        assert (got[:6] == [0, 0, 0, 0, 0, 0]).all()
        # Non-positive sizes are the coordinator's problem; kernel clamps to 0.
        assert got[6] == 0 and got[7] == 0

    def test_oversize_clamps_to_last_queue(self):
        got = _run([params.CHUNK_SIZE + 1, 10**9, 2**30, 8192, 8193, 0, 0, 0],
                   tile=8)
        assert got[0] == params.NUM_QUEUES - 1
        assert got[1] == params.NUM_QUEUES - 1
        assert got[2] == params.NUM_QUEUES - 1
        assert got[3] == params.NUM_QUEUES - 1
        assert got[4] == params.NUM_QUEUES - 1

    def test_production_shape(self):
        rng = np.random.default_rng(0)
        sizes = rng.integers(1, 2 * params.CHUNK_SIZE, params.PLAN_BATCH)
        _run(sizes, tile=params.SIZE_TILE)


class TestProperties:
    @given(st.lists(st.integers(min_value=1, max_value=3 * params.CHUNK_SIZE),
                    min_size=8, max_size=64))
    def test_matches_oracle(self, sizes):
        pad = (-len(sizes)) % 8
        _run(sizes + [1] * pad, tile=8)

    @given(st.integers(min_value=1, max_value=params.CHUNK_SIZE))
    def test_allocated_page_fits_request(self, size):
        q = int(_run([size] * 8, tile=8)[0])
        assert params.PAGE_SIZES[q] >= size
        if q > 0:
            # Minimality: the next smaller page would not fit.
            assert params.PAGE_SIZES[q - 1] < size

    @given(st.lists(st.integers(min_value=1, max_value=params.CHUNK_SIZE),
                    min_size=8, max_size=8))
    def test_monotone_in_size(self, sizes):
        out = _run(sorted(sizes), tile=8)
        assert (np.diff(out) >= 0).all()


def test_tile_must_divide_batch():
    with pytest.raises(AssertionError):
        size_to_queue(jnp.zeros(10, jnp.int32), tile=8)
