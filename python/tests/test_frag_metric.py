"""frag_metric Pallas kernel vs the bit-level python oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import params
from compile.kernels import ref
from compile.kernels.frag_metric import frag_metric


def _run(bm, tile=8):
    bm = np.asarray(bm, np.uint32)
    got = frag_metric(jnp.asarray(bm), tile=tile)
    want = ref.frag_metric(bm)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    return tuple(np.asarray(g) for g in got)


class TestEdges:
    def test_empty_chunk_is_one_run(self):
        free, run, score = _run(np.zeros((8, 4), np.uint32))
        assert (free == 128).all()
        assert (run == 128).all()
        assert (score == 0).all()  # fully contiguous = no fragmentation

    def test_full_chunk_scores_zero(self):
        free, run, score = _run(np.full((8, 4), 0xFFFFFFFF, np.uint32))
        assert (free == 0).all()
        assert (run == 0).all()
        assert (score == 0).all()

    def test_alternating_bits_maximal_fragmentation(self):
        bm = np.full((8, 4), 0x55555555, np.uint32)  # free pages isolated
        free, run, score = _run(bm)
        assert (free == 64).all()
        assert (run == 1).all()
        # 1000 - 1000*1//64 = 985 permille
        assert (score == 985).all()

    def test_run_crossing_word_boundary(self):
        bm = np.full((8, 4), 0xFFFFFFFF, np.uint32)
        # Free bits 30..33: a run of 4 spanning words 0 and 1.
        bm[:, 0] &= ~np.uint32(0b11 << 30)
        bm[:, 1] &= ~np.uint32(0b11)
        free, run, score = _run(bm)
        assert (free == 4).all()
        assert (run == 4).all()
        assert (score == 0).all()

    def test_two_runs_picks_longest(self):
        bm = np.full((8, 2), 0xFFFFFFFF, np.uint32)
        bm[:, 0] &= ~np.uint32(0b111)        # run of 3 at 0..2
        bm[:, 1] &= ~np.uint32(0b11111 << 8) # run of 5 at 40..44
        free, run, _ = _run(bm)
        assert (free == 8).all()
        assert (run == 5).all()

    def test_production_shape(self):
        rng = np.random.default_rng(5)
        bm = rng.integers(0, 2**32, (params.PLAN_CHUNKS, params.BITMAP_WORDS),
                          dtype=np.uint64).astype(np.uint32)
        _run(bm, tile=params.BM_TILE)


class TestProperties:
    @given(st.integers(min_value=0, max_value=2**31))
    def test_random_rows_match_oracle(self, seed):
        rng = np.random.default_rng(seed)
        bm = rng.integers(0, 2**32, (8, 4), dtype=np.uint64).astype(np.uint32)
        _run(bm)

    @given(st.integers(min_value=0, max_value=2**31))
    def test_invariants(self, seed):
        rng = np.random.default_rng(seed)
        bm = rng.integers(0, 2**32, (8, 4), dtype=np.uint64).astype(np.uint32)
        free, run, score = _run(bm)
        assert (run <= free).all()
        assert ((0 <= score) & (score < 1000)).all()
        # Agreement with bitmap_scan's free count.
        _, count = ref.bitmap_scan(jnp.asarray(bm))
        np.testing.assert_array_equal(free, np.asarray(count))

    @given(st.sampled_from([1, 2, 4, 8, 16]))
    def test_word_width_sweep(self, w):
        rng = np.random.default_rng(w)
        bm = rng.integers(0, 2**32, (8, w), dtype=np.uint64).astype(np.uint32)
        _run(bm)


def test_tile_divisibility_enforced():
    with pytest.raises(AssertionError):
        frag_metric(jnp.zeros((10, 4), jnp.uint32), tile=8)
